//! Versioned, CRC-framed engine snapshots with crash-consistent writes.
//!
//! A snapshot captures everything needed to rebuild an [`Engine`] from
//! cold: the retained training view `(X, Y)`, the per-row duplicate
//! multiplicities, and the hyperparameters (kernel, ridge, space,
//! uncertainty flag, fold radius). The maintained inverse is deliberately
//! NOT serialized — [`EngineState::rebuild`] re-factorizes through
//! [`Engine::from_parts`], so a restored engine is *fresher* than the one
//! that crashed (zero accumulated drift) while holding the same weighted
//! training set, and a corrupted inverse can never be resurrected from
//! disk. Recovery probe-validates the rebuilt inverse anyway
//! (`ShardRouter::recover`).
//!
//! ## File format
//!
//! ```text
//! [magic "MIKRRSNP"][version u32]
//! [section SEC_META][section SEC_KERNEL][section SEC_X][section SEC_Y]
//! [section SEC_MULT][section SEC_END]
//! ```
//!
//! each section CRC-framed by [`super::codec::write_section`]. Any flipped
//! bit, truncation, or missing section decodes to a permanent
//! [`Error::Persist`] corruption — the caller's signal to fall back one
//! generation.
//!
//! ## Crash consistency
//!
//! [`write_snapshot`] writes `<name>.snap.tmp`, fsyncs it, atomically
//! renames onto `shard-<id>-gen-<g>.snap`, then fsyncs the directory. A
//! crash anywhere in that sequence leaves either the previous generation
//! intact (tmp file garbage is ignored by [`list_generations`]) or the new
//! generation fully durable — never a half-visible snapshot. Every
//! boundary carries a [`KillPoint`] so the chaos matrix can die exactly
//! there.

use std::fs;
use std::io::Write;
use std::path::{Path, PathBuf};

use crate::config::Space;
use crate::coordinator::engine::Engine;
use crate::error::{Error, Result};
use crate::health::fault::KillPoint;
use crate::kernels::Kernel;
use crate::linalg::Mat;

use super::codec::{
    put_f64, put_u32, put_u64, put_u8, read_section, write_section, Cursor,
};
use super::kill;

/// File magic (8 bytes).
pub const MAGIC: &[u8; 8] = b"MIKRRSNP";
/// Codec version; bump on any layout change.
pub const VERSION: u32 = 1;

const SEC_META: u32 = 1;
const SEC_KERNEL: u32 = 2;
const SEC_X: u32 = 3;
const SEC_Y: u32 = 4;
const SEC_MULT: u32 = 5;
const SEC_END: u32 = 0xE0F;

/// Everything a snapshot persists about one engine.
#[derive(Clone, Debug)]
pub struct EngineState {
    /// Snapshot generation (monotone per shard).
    pub generation: u64,
    /// Published epoch at capture time.
    pub epoch: u64,
    /// Highest applied event sequence number at capture time — the replay
    /// and re-feed cutoff.
    pub high_seq: u64,
    /// Operating space.
    pub space: Space,
    /// Whether the engine carries a KBR twin.
    pub with_uncertainty: bool,
    /// Ridge parameter.
    pub ridge: f64,
    /// Duplicate-fold radius.
    pub fold_eps: Option<f64>,
    /// Kernel.
    pub kernel: Kernel,
    /// Training features, engine order.
    pub x: Mat,
    /// Multiplicity-averaged targets `(N, D)`, engine order.
    pub y: Mat,
    /// Per-row duplicate multiplicities.
    pub mult: Vec<f64>,
}

impl EngineState {
    /// Capture an engine's persistent parts.
    pub fn capture(e: &Engine, generation: u64, epoch: u64, high_seq: u64) -> Self {
        let (x, y) = e.training_view();
        Self {
            generation,
            epoch,
            high_seq,
            space: e.space(),
            with_uncertainty: e.has_uncertainty(),
            ridge: e.ridge(),
            fold_eps: e.fold_eps(),
            kernel: e.kernel().clone(),
            x: x.clone(),
            y: y.clone(),
            mult: e.multiplicities().to_vec(),
        }
    }

    /// Re-factorize an engine from the captured parts (fresh maintained
    /// inverse, replayed multiplicities).
    pub fn rebuild(&self) -> Result<Engine> {
        Engine::from_parts(
            &self.x,
            &self.y,
            &self.mult,
            &self.kernel,
            self.ridge,
            self.space,
            self.with_uncertainty,
            self.fold_eps,
        )
    }

    /// Serialize to the on-disk byte form.
    pub fn encode(&self) -> Vec<u8> {
        let floats = self.x.as_slice().len() + self.y.as_slice().len() + self.mult.len();
        let mut out = Vec::with_capacity(64 + 8 * floats);
        out.extend_from_slice(MAGIC);
        put_u32(&mut out, VERSION);

        let mut p = Vec::new();
        put_u64(&mut p, self.generation);
        put_u64(&mut p, self.epoch);
        put_u64(&mut p, self.high_seq);
        put_space(&mut p, self.space);
        put_u8(&mut p, self.with_uncertainty as u8);
        put_f64(&mut p, self.ridge);
        match self.fold_eps {
            Some(eps) => {
                put_u8(&mut p, 1);
                put_f64(&mut p, eps);
            }
            None => {
                put_u8(&mut p, 0);
                put_f64(&mut p, 0.0);
            }
        }
        write_section(&mut out, SEC_META, &p);

        p.clear();
        put_kernel(&mut p, &self.kernel);
        write_section(&mut out, SEC_KERNEL, &p);

        for (tag, m) in [(SEC_X, &self.x), (SEC_Y, &self.y)] {
            p.clear();
            put_u64(&mut p, m.rows() as u64);
            put_u64(&mut p, m.cols() as u64);
            for &v in m.as_slice() {
                put_f64(&mut p, v);
            }
            write_section(&mut out, tag, &p);
        }

        p.clear();
        put_u64(&mut p, self.mult.len() as u64);
        for &v in &self.mult {
            put_f64(&mut p, v);
        }
        write_section(&mut out, SEC_MULT, &p);

        write_section(&mut out, SEC_END, &[]);
        out
    }

    /// Decode from the on-disk byte form, verifying every CRC.
    pub fn decode(bytes: &[u8]) -> Result<Self> {
        const CTX: &str = "snapshot::decode";
        let corrupt = |d: String| Error::persist_corruption(CTX, d);
        let mut cur = Cursor::new(bytes, CTX);
        let magic = cur.take_bytes(MAGIC.len())?;
        if magic != MAGIC {
            return Err(corrupt(format!("bad magic {magic:02x?}")));
        }
        let version = cur.take_u32()?;
        if version != VERSION {
            return Err(corrupt(format!("unsupported version {version}")));
        }

        let mut meta = None;
        let mut kernel = None;
        let mut x = None;
        let mut y = None;
        let mut mult = None;
        let mut ended = false;
        while !ended {
            let (tag, payload) = read_section(&mut cur, CTX)?;
            let mut pc = Cursor::new(payload, CTX);
            match tag {
                SEC_META => {
                    let generation = pc.take_u64()?;
                    let epoch = pc.take_u64()?;
                    let high_seq = pc.take_u64()?;
                    let space = take_space(&mut pc)?;
                    let with_uncertainty = match pc.take_u8()? {
                        0 => false,
                        1 => true,
                        b => return Err(corrupt(format!("bad bool {b}"))),
                    };
                    let ridge = pc.take_f64()?;
                    let has_eps = pc.take_u8()?;
                    let eps = pc.take_f64()?;
                    let fold_eps = match has_eps {
                        0 => None,
                        1 => Some(eps),
                        b => return Err(corrupt(format!("bad fold flag {b}"))),
                    };
                    meta = Some((
                        generation,
                        epoch,
                        high_seq,
                        space,
                        with_uncertainty,
                        ridge,
                        fold_eps,
                    ));
                }
                SEC_KERNEL => {
                    kernel = Some(take_kernel(&mut pc)?);
                }
                SEC_X | SEC_Y => {
                    let rows = pc.take_len()?;
                    let cols = pc.take_len()?;
                    let n = rows
                        .checked_mul(cols)
                        .and_then(|n| n.checked_mul(8).map(|_| n))
                        .ok_or_else(|| {
                            corrupt(format!("matrix {rows}x{cols} overflows"))
                        })?;
                    if pc.remaining() != n * 8 {
                        return Err(corrupt(format!(
                            "matrix section {tag:#x}: {rows}x{cols} needs {} bytes, has {}",
                            n * 8,
                            pc.remaining()
                        )));
                    }
                    let mut data = Vec::with_capacity(n);
                    for _ in 0..n {
                        data.push(pc.take_f64()?);
                    }
                    let m = Mat::from_vec(rows, cols, data)?;
                    if tag == SEC_X {
                        x = Some(m);
                    } else {
                        y = Some(m);
                    }
                }
                SEC_MULT => {
                    let n = pc.take_len()?;
                    if n.checked_mul(8).is_none() {
                        return Err(corrupt(format!("mult length {n} overflows")));
                    }
                    if pc.remaining() != n * 8 {
                        return Err(corrupt(format!(
                            "mult section: {n} entries need {} bytes, has {}",
                            n * 8,
                            pc.remaining()
                        )));
                    }
                    let mut v = Vec::with_capacity(n);
                    for _ in 0..n {
                        v.push(pc.take_f64()?);
                    }
                    mult = Some(v);
                }
                SEC_END => ended = true,
                t => return Err(corrupt(format!("unknown section tag {t:#x}"))),
            }
            if !ended && !pc.is_empty() {
                return Err(corrupt(format!("section {tag:#x} has trailing bytes")));
            }
        }
        if !cur.is_empty() {
            return Err(corrupt("trailing bytes after end section".into()));
        }
        let (generation, epoch, high_seq, space, with_uncertainty, ridge, fold_eps) =
            meta.ok_or_else(|| corrupt("missing meta section".into()))?;
        let kernel = kernel.ok_or_else(|| corrupt("missing kernel section".into()))?;
        let x = x.ok_or_else(|| corrupt("missing X section".into()))?;
        let y = y.ok_or_else(|| corrupt("missing Y section".into()))?;
        let mult = mult.ok_or_else(|| corrupt("missing mult section".into()))?;
        if x.rows() != y.rows() || mult.len() != y.rows() {
            return Err(corrupt(format!(
                "inconsistent stores: x {}x{}, y {}x{}, mult {}",
                x.rows(),
                x.cols(),
                y.rows(),
                y.cols(),
                mult.len()
            )));
        }
        Ok(Self {
            generation,
            epoch,
            high_seq,
            space,
            with_uncertainty,
            ridge,
            fold_eps,
            kernel,
            x,
            y,
            mult,
        })
    }
}

/// Canonical snapshot filename for `(shard, generation)`.
pub fn snapshot_path(dir: &Path, shard_id: usize, generation: u64) -> PathBuf {
    dir.join(format!("shard-{shard_id}-gen-{generation}.snap"))
}

/// Write a snapshot crash-consistently: tmp file → fsync → atomic rename
/// → directory fsync. Each boundary carries its [`KillPoint`].
pub fn write_snapshot(dir: &Path, shard_id: usize, state: &EngineState) -> Result<()> {
    const CTX: &str = "snapshot::write";
    let bytes = state.encode();
    let final_path = snapshot_path(dir, shard_id, state.generation);
    let tmp_path = final_path.with_extension("snap.tmp");
    {
        let mut f =
            fs::File::create(&tmp_path).map_err(|e| Error::persist_io(CTX, e))?;
        if kill::fires(KillPoint::SnapTmpTorn) {
            // simulate dying mid-write: half the body lands, then nothing
            let _ = f.write_all(&bytes[..bytes.len() / 2]);
            return Err(kill::killed(CTX, KillPoint::SnapTmpTorn));
        }
        f.write_all(&bytes).map_err(|e| Error::persist_io(CTX, e))?;
        if kill::fires(KillPoint::SnapTmpFull) {
            return Err(kill::killed(CTX, KillPoint::SnapTmpFull));
        }
        if kill::fires(KillPoint::SnapTmpFsync) {
            return Err(kill::killed(CTX, KillPoint::SnapTmpFsync));
        }
        f.sync_all().map_err(|e| Error::persist_io(CTX, e))?;
    }
    if kill::fires(KillPoint::SnapRename) {
        return Err(kill::killed(CTX, KillPoint::SnapRename));
    }
    fs::rename(&tmp_path, &final_path).map_err(|e| Error::persist_io(CTX, e))?;
    if kill::fires(KillPoint::SnapDirFsync) {
        return Err(kill::killed(CTX, KillPoint::SnapDirFsync));
    }
    sync_dir(dir).map_err(|e| Error::persist_io(CTX, e))?;
    Ok(())
}

/// Read and decode one snapshot file.
pub fn read_snapshot(path: &Path) -> Result<EngineState> {
    let bytes = fs::read(path).map_err(|e| Error::persist_io("snapshot::read", e))?;
    EngineState::decode(&bytes)
}

/// Shard snapshot generations present in `dir`, ascending. Ignores tmp
/// garbage, quarantined `.corrupt` files, and other shards' files.
pub fn list_generations(dir: &Path, shard_id: usize) -> Result<Vec<u64>> {
    const CTX: &str = "snapshot::list";
    let prefix = format!("shard-{shard_id}-gen-");
    let mut gens = Vec::new();
    let entries = match fs::read_dir(dir) {
        Ok(e) => e,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(gens),
        Err(e) => return Err(Error::persist_io(CTX, e)),
    };
    for entry in entries {
        let entry = entry.map_err(|e| Error::persist_io(CTX, e))?;
        let name = entry.file_name();
        let Some(name) = name.to_str() else { continue };
        let Some(rest) = name.strip_prefix(&prefix) else { continue };
        let Some(gen) = rest.strip_suffix(".snap") else { continue };
        if let Ok(g) = gen.parse::<u64>() {
            gens.push(g);
        }
    }
    gens.sort_unstable();
    Ok(gens)
}

/// Quarantine a corrupt snapshot out of the generation listing (renamed to
/// `<name>.corrupt`, kept for post-mortem).
pub fn quarantine_snapshot(path: &Path) -> Result<()> {
    let mut corrupt = path.as_os_str().to_owned();
    corrupt.push(".corrupt");
    fs::rename(path, PathBuf::from(corrupt))
        .map_err(|e| Error::persist_io("snapshot::quarantine", e))
}

/// fsync a directory so a completed rename is durable.
pub(crate) fn sync_dir(dir: &Path) -> std::io::Result<()> {
    fs::File::open(dir)?.sync_all()
}

// ---- shared enum codecs (also used by the router-meta file) ----

pub(crate) fn put_space(out: &mut Vec<u8>, space: Space) {
    put_u8(out, match space {
        Space::Intrinsic => 0,
        Space::Empirical => 1,
    });
}

pub(crate) fn take_space(pc: &mut Cursor<'_>) -> Result<Space> {
    match pc.take_u8()? {
        0 => Ok(Space::Intrinsic),
        1 => Ok(Space::Empirical),
        s => Err(Error::persist_corruption("take_space", format!("unknown space tag {s}"))),
    }
}

pub(crate) fn put_kernel(out: &mut Vec<u8>, k: &Kernel) {
    match k {
        Kernel::Linear => put_u8(out, 0),
        Kernel::Poly { degree, coef0 } => {
            put_u8(out, 1);
            put_u32(out, *degree);
            put_f64(out, *coef0);
        }
        Kernel::Rbf { gamma } => {
            put_u8(out, 2);
            put_f64(out, *gamma);
        }
    }
}

pub(crate) fn take_kernel(pc: &mut Cursor<'_>) -> Result<Kernel> {
    match pc.take_u8()? {
        0 => Ok(Kernel::Linear),
        1 => {
            let degree = pc.take_u32()?;
            let coef0 = pc.take_f64()?;
            Ok(Kernel::Poly { degree, coef0 })
        }
        2 => Ok(Kernel::Rbf { gamma: pc.take_f64()? }),
        k => Err(Error::persist_corruption("take_kernel", format!("unknown kernel tag {k}"))),
    }
}
