//! Per-shard write-ahead log of applied event batches.
//!
//! One WAL *segment* per snapshot generation: `shard-<id>-wal-<gen>.log`
//! holds everything applied *after* snapshot generation `gen` landed.
//! Recovery loads the newest valid snapshot and replays the segments from
//! that generation forward; checkpointing opens a fresh segment and
//! garbage-collects the ones older generations covered.
//!
//! ## Record framing
//!
//! ```text
//! segment  = [magic "MIKRRWAL"][version u32] record*
//! record   = [len u32][payload: len bytes][crc32(payload) u32]
//! payload  = [kind u8][seq u64] body
//! ```
//!
//! `seq` is the monotone per-shard sequence the record publishes (the
//! epoch the round produced). Replay is idempotent by `seq`: records at or
//! below the recovered engine's epoch are skipped, so a crash *after* the
//! snapshot but *before* WAL truncation never double-applies.
//!
//! ## Torn tails
//!
//! A crash mid-append leaves a partial record at the tail. On read, the
//! first record that is truncated or fails its CRC ends the segment: the
//! valid prefix is returned and (when `repair` is set) the file is
//! truncated back to it, exactly like a journaling filesystem's log
//! replay. A *live* append that fails with a real I/O error also rolls the
//! file back to its pre-append length so a later append cannot interleave
//! with the torn bytes — but a chaos kill deliberately skips that repair,
//! because the simulated process is dead.

use std::fs;
use std::io::{Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::health::fault::KillPoint;
use crate::streaming::StreamEvent;

use super::codec::{frame_crc, put_u32, put_u64, put_u8};
use super::kill;

/// Segment magic (8 bytes).
pub const WAL_MAGIC: &[u8; 8] = b"MIKRRWAL";
/// Segment codec version.
pub const WAL_VERSION: u32 = 1;

const HEADER_LEN: u64 = 12;

const KIND_BATCH: u8 = 0;
const KIND_EVICT: u8 = 1;
const KIND_HEAL: u8 = 2;

/// One durable log entry: a state transition the shard applied.
#[derive(Clone, Debug)]
pub enum WalRecord {
    /// A validated event batch entering [`apply_batch`](crate::serve::Shard).
    Batch {
        /// Sequence the round publishes (engine epoch after apply).
        seq: u64,
        /// The filtered, validated events, in apply order.
        events: Vec<StreamEvent>,
    },
    /// An outlier-eviction round.
    Evict {
        /// Sequence the eviction publishes.
        seq: u64,
    },
    /// A self-heal refactorization round.
    Heal {
        /// Sequence the heal publishes.
        seq: u64,
    },
}

impl WalRecord {
    /// The sequence this record publishes.
    pub fn seq(&self) -> u64 {
        match self {
            WalRecord::Batch { seq, .. }
            | WalRecord::Evict { seq }
            | WalRecord::Heal { seq } => *seq,
        }
    }

    fn encode_payload(&self, out: &mut Vec<u8>) {
        match self {
            WalRecord::Batch { seq, events } => {
                put_u8(out, KIND_BATCH);
                put_u64(out, *seq);
                put_u32(out, events.len() as u32);
                for e in events {
                    e.encode_into(out);
                }
            }
            WalRecord::Evict { seq } => {
                put_u8(out, KIND_EVICT);
                put_u64(out, *seq);
            }
            WalRecord::Heal { seq } => {
                put_u8(out, KIND_HEAL);
                put_u64(out, *seq);
            }
        }
    }

    fn decode_payload(buf: &[u8]) -> Result<WalRecord> {
        const CTX: &str = "WalRecord::decode";
        let corrupt = |d: String| Error::persist_corruption(CTX, d);
        if buf.len() < 9 {
            return Err(corrupt(format!("payload of {} bytes has no header", buf.len())));
        }
        let kind = buf[0];
        let seq = u64::from_le_bytes(buf[1..9].try_into().unwrap());
        let mut pos = 9;
        match kind {
            KIND_BATCH => {
                if buf.len() < pos + 4 {
                    return Err(corrupt("batch record missing count".into()));
                }
                let n = u32::from_le_bytes(buf[pos..pos + 4].try_into().unwrap()) as usize;
                pos += 4;
                let mut events = Vec::with_capacity(n.min(1 + buf.len() / 24));
                for _ in 0..n {
                    events.push(StreamEvent::decode_from(buf, &mut pos)?);
                }
                if pos != buf.len() {
                    return Err(corrupt(format!(
                        "batch record has {} trailing bytes",
                        buf.len() - pos
                    )));
                }
                Ok(WalRecord::Batch { seq, events })
            }
            KIND_EVICT | KIND_HEAL => {
                if pos != buf.len() {
                    return Err(corrupt("oversized control record".into()));
                }
                Ok(if kind == KIND_EVICT {
                    WalRecord::Evict { seq }
                } else {
                    WalRecord::Heal { seq }
                })
            }
            k => Err(corrupt(format!("unknown record kind {k}"))),
        }
    }
}

/// Canonical segment filename for `(shard, generation)`.
pub fn wal_path(dir: &Path, shard_id: usize, generation: u64) -> PathBuf {
    dir.join(format!("shard-{shard_id}-wal-{generation}.log"))
}

/// An open, append-only WAL segment.
pub struct Wal {
    file: fs::File,
    path: PathBuf,
    /// Length of the valid prefix — the rollback point for failed appends.
    len: u64,
}

impl Wal {
    /// Create a fresh segment (header written and fsynced). Truncates any
    /// stale file at the same path.
    pub fn create(dir: &Path, shard_id: usize, generation: u64) -> Result<Self> {
        const CTX: &str = "Wal::create";
        let path = wal_path(dir, shard_id, generation);
        let mut file = fs::File::create(&path).map_err(|e| Error::persist_io(CTX, e))?;
        let mut header = Vec::with_capacity(HEADER_LEN as usize);
        header.extend_from_slice(WAL_MAGIC);
        put_u32(&mut header, WAL_VERSION);
        file.write_all(&header).map_err(|e| Error::persist_io(CTX, e))?;
        file.sync_all().map_err(|e| Error::persist_io(CTX, e))?;
        Ok(Self { file, path, len: HEADER_LEN })
    }

    /// Re-open an existing segment for appending, truncating any torn
    /// tail first. Returns `(wal, records, torn)`.
    pub fn open(
        dir: &Path,
        shard_id: usize,
        generation: u64,
    ) -> Result<(Self, Vec<WalRecord>, bool)> {
        const CTX: &str = "Wal::open";
        let path = wal_path(dir, shard_id, generation);
        let (records, valid_len, torn) = scan(&path)?;
        let file = fs::OpenOptions::new()
            .write(true)
            .open(&path)
            .map_err(|e| Error::persist_io(CTX, e))?;
        if torn {
            file.set_len(valid_len).map_err(|e| Error::persist_io(CTX, e))?;
            file.sync_all().map_err(|e| Error::persist_io(CTX, e))?;
        }
        Ok((Self { file, path, len: valid_len }, records, torn))
    }

    /// The segment's path.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Length of the durable valid prefix.
    pub fn len(&self) -> u64 {
        self.len
    }

    /// True when the segment holds no records.
    pub fn is_empty(&self) -> bool {
        self.len <= HEADER_LEN
    }

    /// Append one record durably (write + fsync). `scratch` is reused
    /// across calls to keep the hot path allocation-light.
    pub fn append(&mut self, rec: &WalRecord, scratch: &mut Vec<u8>) -> Result<()> {
        const CTX: &str = "Wal::append";
        scratch.clear();
        // reserve the frame header, encode payload, then backfill
        put_u32(scratch, 0);
        rec.encode_payload(scratch);
        let payload_len = scratch.len() - 4;
        scratch[..4].copy_from_slice(&(payload_len as u32).to_le_bytes());
        let crc = frame_crc(&scratch[4..]);
        put_u32(scratch, crc);

        // position explicitly at the valid prefix: a reopened segment's
        // cursor starts at 0, and a rolled-back append leaves it past EOF
        self.file
            .seek(SeekFrom::Start(self.len))
            .map_err(|e| Error::persist_io(CTX, e))?;
        if kill::fires(KillPoint::WalAppendTorn) {
            // die mid-write: half the frame lands, and nobody repairs it —
            // the simulated process is gone (recovery truncates the tail)
            let _ = self.file.write_all(&scratch[..scratch.len() / 2]);
            return Err(kill::killed(CTX, KillPoint::WalAppendTorn));
        }
        if let Err(e) = self.file.write_all(scratch) {
            // live process, real I/O failure: roll the file back to the
            // valid prefix so a retried append can't interleave torn bytes
            let _ = self.file.set_len(self.len);
            return Err(Error::persist_io(CTX, e));
        }
        if kill::fires(KillPoint::WalAppendFull) {
            return Err(kill::killed(CTX, KillPoint::WalAppendFull));
        }
        if kill::fires(KillPoint::WalFsync) {
            return Err(kill::killed(CTX, KillPoint::WalFsync));
        }
        if let Err(e) = self.file.sync_data() {
            let _ = self.file.set_len(self.len);
            return Err(Error::persist_io(CTX, e));
        }
        self.len += scratch.len() as u64;
        Ok(())
    }
}

/// Read every valid record of a segment. A missing file reads as empty;
/// a truncated or CRC-failing tail ends the scan (`torn = true`), without
/// modifying the file (use [`Wal::open`] to also truncate it).
pub fn read_records(path: &Path) -> Result<(Vec<WalRecord>, bool)> {
    let (records, _, torn) = scan(path)?;
    Ok((records, torn))
}

/// Scan a segment: `(records, valid_prefix_len, torn)`.
fn scan(path: &Path) -> Result<(Vec<WalRecord>, u64, bool)> {
    const CTX: &str = "wal::scan";
    let bytes = match fs::read(path) {
        Ok(b) => b,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
            return Ok((Vec::new(), HEADER_LEN, false))
        }
        Err(e) => return Err(Error::persist_io(CTX, e)),
    };
    if bytes.len() < HEADER_LEN as usize {
        // creation crashed before the header was durable: an empty segment
        return Ok((Vec::new(), bytes.len() as u64, true));
    }
    if &bytes[..8] != WAL_MAGIC {
        return Err(Error::persist_corruption(CTX, format!("bad magic {:02x?}", &bytes[..8])));
    }
    let version = u32::from_le_bytes(bytes[8..12].try_into().unwrap());
    if version != WAL_VERSION {
        return Err(Error::persist_corruption(CTX, format!("unsupported version {version}")));
    }
    let mut records = Vec::new();
    let mut pos = HEADER_LEN as usize;
    loop {
        let remaining = bytes.len() - pos;
        if remaining == 0 {
            return Ok((records, pos as u64, false));
        }
        if remaining < 4 {
            return Ok((records, pos as u64, true));
        }
        let len = u32::from_le_bytes(bytes[pos..pos + 4].try_into().unwrap()) as usize;
        if remaining < 4 + len + 4 {
            return Ok((records, pos as u64, true));
        }
        let payload = &bytes[pos + 4..pos + 4 + len];
        let stored = u32::from_le_bytes(bytes[pos + 4 + len..pos + 8 + len].try_into().unwrap());
        if frame_crc(payload) != stored {
            // a flipped bit anywhere in the record: the byte stream after
            // it cannot be trusted, so the valid prefix ends here
            return Ok((records, pos as u64, true));
        }
        records.push(WalRecord::decode_payload(payload)?);
        pos += 8 + len;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::ScratchDir;

    fn sample_records() -> Vec<WalRecord> {
        vec![
            WalRecord::Batch {
                seq: 5,
                events: vec![
                    StreamEvent::single(vec![1.0, -2.5], 0.75, 3, 41),
                    StreamEvent::multi(vec![0.0, 1e-12], &[1.0, 2.0, 3.0], 1, 42),
                ],
            },
            WalRecord::Evict { seq: 6 },
            WalRecord::Heal { seq: 7 },
            WalRecord::Batch { seq: 8, events: Vec::new() },
        ]
    }

    #[test]
    fn segment_round_trips() {
        let dir = ScratchDir::new("wal-rt");
        let mut wal = Wal::create(dir.path(), 0, 1).unwrap();
        let mut scratch = Vec::new();
        for r in &sample_records() {
            wal.append(r, &mut scratch).unwrap();
        }
        let (got, torn) = read_records(&wal_path(dir.path(), 0, 1)).unwrap();
        assert!(!torn);
        assert_eq!(got.len(), 4);
        assert_eq!(got.iter().map(WalRecord::seq).collect::<Vec<_>>(), vec![5, 6, 7, 8]);
        match &got[0] {
            WalRecord::Batch { events, .. } => {
                assert_eq!(events.len(), 2);
                assert_eq!(events[0].x, vec![1.0, -2.5]);
                assert_eq!(events[1].y_tail, vec![2.0, 3.0]);
                assert_eq!(events[1].seq, 42);
            }
            other => panic!("expected batch, got {other:?}"),
        }
        assert!(matches!(got[1], WalRecord::Evict { seq: 6 }));
        assert!(matches!(got[2], WalRecord::Heal { seq: 7 }));
    }

    #[test]
    fn missing_segment_reads_empty() {
        let dir = ScratchDir::new("wal-missing");
        let (recs, torn) = read_records(&wal_path(dir.path(), 9, 9)).unwrap();
        assert!(recs.is_empty());
        assert!(!torn);
    }

    #[test]
    fn torn_tail_is_detected_and_truncated_on_open() {
        let dir = ScratchDir::new("wal-torn");
        let mut wal = Wal::create(dir.path(), 0, 1).unwrap();
        let mut scratch = Vec::new();
        wal.append(&WalRecord::Evict { seq: 1 }, &mut scratch).unwrap();
        wal.append(&WalRecord::Heal { seq: 2 }, &mut scratch).unwrap();
        let good_len = wal.len();
        drop(wal);
        // hand-tear: append half of a third record's frame
        let path = wal_path(dir.path(), 0, 1);
        let mut torn_frame = Vec::new();
        put_u32(&mut torn_frame, 9);
        put_u8(&mut torn_frame, KIND_EVICT);
        let mut f = fs::OpenOptions::new().append(true).open(&path).unwrap();
        f.write_all(&torn_frame).unwrap();
        drop(f);
        let (recs, torn) = read_records(&path).unwrap();
        assert!(torn, "partial frame must read as torn");
        assert_eq!(recs.len(), 2, "valid prefix survives");
        let (wal, recs, torn) = Wal::open(dir.path(), 0, 1).unwrap();
        assert!(torn);
        assert_eq!(recs.len(), 2);
        assert_eq!(wal.len(), good_len, "open truncated back to the valid prefix");
        assert_eq!(fs::metadata(&path).unwrap().len(), good_len);
        // and a fresh append after repair extends cleanly
        let mut wal = wal;
        wal.append(&WalRecord::Evict { seq: 3 }, &mut scratch).unwrap();
        let (recs, torn) = read_records(&path).unwrap();
        assert!(!torn);
        assert_eq!(recs.iter().map(WalRecord::seq).collect::<Vec<_>>(), vec![1, 2, 3]);
    }

    #[test]
    fn mid_record_bit_flip_ends_the_valid_prefix() {
        let dir = ScratchDir::new("wal-flip");
        let mut wal = Wal::create(dir.path(), 0, 1).unwrap();
        let mut scratch = Vec::new();
        wal.append(&WalRecord::Evict { seq: 1 }, &mut scratch).unwrap();
        let flip_at = wal.len() as usize - 6; // inside record 1's payload
        wal.append(&WalRecord::Heal { seq: 2 }, &mut scratch).unwrap();
        drop(wal);
        let path = wal_path(dir.path(), 0, 1);
        let mut bytes = fs::read(&path).unwrap();
        bytes[flip_at] ^= 0x10;
        fs::write(&path, &bytes).unwrap();
        let (recs, torn) = read_records(&path).unwrap();
        assert!(torn);
        assert!(recs.is_empty(), "nothing after the flipped record is trusted");
    }

    #[test]
    fn bad_magic_is_corruption_not_torn() {
        let dir = ScratchDir::new("wal-magic");
        let path = wal_path(dir.path(), 0, 1);
        fs::write(&path, b"NOTAWAL!....").unwrap();
        let err = read_records(&path).unwrap_err();
        assert!(!err.is_transient(), "foreign bytes are permanent corruption");
    }
}
