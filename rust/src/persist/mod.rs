//! Durable shards: crash-safe engine snapshots and per-shard write-ahead
//! logs, dependency-free over `std::fs`.
//!
//! ## Layering
//!
//! - [`crc`] / [`codec`] — CRC-32 framing and the little-endian binary
//!   primitives both file formats share.
//! - [`snapshot`] — versioned engine snapshots (`shard-<k>-gen-<g>.snap`),
//!   written crash-consistently (tmp + fsync + atomic rename + dir fsync)
//!   and rebuilt through a fresh factorization on load.
//! - [`wal`] — per-shard, per-generation append-only logs of applied
//!   rounds (`shard-<k>-wal-<g>.log`), CRC per record, torn tails
//!   truncated on open.
//! - [`store`] — the per-shard driver gluing them together: write-ahead
//!   logging, checkpoint cadence, generation GC, the recovery scan, and
//!   the fleet-level `router.meta` file.
//! - [`kill`] — chaos-gated crash injection at every write/fsync/rename
//!   boundary (the [`crate::health::fault::KillPoint`] catalogue); a
//!   constant no-op outside `--features chaos`.
//!
//! ## Durability contract
//!
//! After any crash — at *any* kill point — recovery restores every shard
//! to exactly the state reachable from the durable prefix: the newest
//! intact snapshot generation plus idempotent WAL replay (by sequence
//! number) of everything logged after it. A corrupted newest snapshot
//! falls back one generation and replays the correspondingly longer WAL
//! suffix; unrecoverable shards are quarantined through the serve layer's
//! health machinery rather than panicking the fleet. The recovery matrix
//! test (`rust/tests/recovery_kill_matrix.rs`) proves recovered
//! predictions match an uninterrupted control run at every kill point.

pub mod codec;
pub mod crc;
pub mod kill;
pub mod snapshot;
pub mod store;
pub mod wal;

pub use snapshot::EngineState;
pub use store::{recover_shard, DurabilityConfig, RecoveredShard, RouterMeta, ShardStore};
pub use wal::WalRecord;
