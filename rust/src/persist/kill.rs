//! Crash-fault injection for the durability write path.
//!
//! The [`KillPoint`] catalogue lives in [`crate::health::fault`] (plain
//! data, always compiled); this module holds the process-wide arming
//! registry and is the only place the snapshot/WAL code consults. Without
//! the `chaos` feature, [`fires`] is a constant `false` and the whole
//! mechanism compiles to nothing — production builds carry zero injection
//! code, same contract as the serve-layer fault hooks.
//!
//! Death semantics: arming registers ONE kill point. The first persist
//! operation to reach it "dies" — [`fires`] returns `true` there and at
//! **every** persist boundary afterwards, because a crashed process does
//! not keep writing. The recovery matrix test arms a point, drives traffic
//! until [`fired`] reports the crash, abandons the live router (the
//! simulated dead process), calls [`disarm`], and then recovers from the
//! state directory alone.
//!
//! The registry is a process-global: tests that arm kill points must
//! serialize on a shared lock (see `rust/tests/recovery_kill_matrix.rs`)
//! or run with `--test-threads=1`.

use crate::error::Error;
use crate::health::fault::KillPoint;

#[cfg(feature = "chaos")]
mod registry {
    use super::KillPoint;
    use std::sync::Mutex;

    struct Armed {
        point: KillPoint,
        fired: bool,
    }

    static ARMED: Mutex<Option<Armed>> = Mutex::new(None);

    pub fn arm(point: KillPoint) {
        *ARMED.lock().expect("kill registry poisoned") =
            Some(Armed { point, fired: false });
    }

    pub fn disarm() {
        *ARMED.lock().expect("kill registry poisoned") = None;
    }

    pub fn fired() -> bool {
        ARMED
            .lock()
            .expect("kill registry poisoned")
            .as_ref()
            .is_some_and(|a| a.fired)
    }

    pub fn should_kill(point: KillPoint) -> bool {
        let mut g = ARMED.lock().expect("kill registry poisoned");
        match g.as_mut() {
            // once dead, every persist boundary fails
            Some(a) if a.fired || a.point == point => {
                a.fired = true;
                true
            }
            _ => false,
        }
    }
}

/// Arm one kill point (chaos builds only). Replaces any previous arming.
#[cfg(feature = "chaos")]
pub fn arm(point: KillPoint) {
    registry::arm(point);
}

/// Clear the registry — the step between "the process died" and "a fresh
/// process starts recovery" (chaos builds only).
#[cfg(feature = "chaos")]
pub fn disarm() {
    registry::disarm();
}

/// True once the armed kill point has fired (chaos builds only).
#[cfg(feature = "chaos")]
pub fn fired() -> bool {
    registry::fired()
}

/// Does the armed kill point fire at this boundary? Constant `false`
/// without the `chaos` feature.
#[inline(always)]
pub fn fires(point: KillPoint) -> bool {
    #[cfg(feature = "chaos")]
    {
        registry::should_kill(point)
    }
    #[cfg(not(feature = "chaos"))]
    {
        let _ = point;
        false
    }
}

/// The simulated crash error: a *transient* persist failure (the
/// filesystem did not corrupt anything — the process just stopped), so
/// the supervisor's classification treats it exactly like a real torn
/// write or failed fsync.
pub fn killed(context: &'static str, point: KillPoint) -> Error {
    Error::persist_io(
        context,
        std::io::Error::new(
            std::io::ErrorKind::Interrupted,
            format!("chaos kill at {point:?}"),
        ),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn killed_error_is_transient_persist() {
        let e = killed("Wal::append", KillPoint::WalFsync);
        assert!(e.is_transient());
        assert!(e.to_string().contains("WalFsync"));
        assert!(matches!(e, Error::Persist { .. }));
    }

    #[cfg(not(feature = "chaos"))]
    #[test]
    fn without_chaos_nothing_fires() {
        for p in KillPoint::ALL {
            assert!(!fires(p));
        }
    }

    #[cfg(feature = "chaos")]
    #[test]
    fn armed_point_fires_once_then_everything_fails() {
        // serialized against other chaos tests by being the only registry
        // test in this crate's unit suite
        arm(KillPoint::WalFsync);
        assert!(!fired());
        assert!(!fires(KillPoint::WalAppendTorn), "other points pass until death");
        assert!(fires(KillPoint::WalFsync), "the armed point kills");
        assert!(fired());
        assert!(fires(KillPoint::SnapGc), "dead processes do not keep writing");
        disarm();
        assert!(!fires(KillPoint::WalFsync), "disarmed registry is inert");
    }
}
