//! Per-shard durability driver: checkpoint scheduling, WAL segment
//! rotation, generation GC, and the recovery scan.
//!
//! A [`ShardStore`] owns one shard's on-disk state:
//!
//! ```text
//! <dir>/router.meta                    fleet topology + round policy
//! <dir>/shard-<k>-gen-<g>.snap         engine snapshot, generation g
//! <dir>/shard-<k>-wal-<g>.log          events applied AFTER snapshot g
//! ```
//!
//! The live write path is *write-ahead*: the shard logs a round's batch
//! ([`ShardStore::log_batch`]) before applying it, and after a successful
//! round calls [`ShardStore::maybe_checkpoint`] — every `checkpoint_every`
//! rounds that snapshots the engine at generation `g+1`, opens WAL segment
//! `g+1`, and garbage-collects generations older than the retention
//! window. Keeping `keep_generations >= 2` means a corrupted newest
//! snapshot still recovers: the scan falls back one generation and replays
//! a longer WAL suffix instead.
//!
//! [`recover_shard`] is the read side: pick the newest snapshot that
//! decodes cleanly (quarantining corrupt ones as `.corrupt` and counting
//! `snapshot_fallbacks`), then collect every WAL record from that
//! generation forward — including segments *newer* than the chosen
//! snapshot, which exist exactly when the newest snapshot was the corrupt
//! one. Torn tails are truncated and counted (`torn_tails_truncated`).

use std::fs;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use crate::coordinator::engine::Engine;
use crate::coordinator::CoordinatorConfig;
use crate::error::{Error, Result};
use crate::health::fault::KillPoint;
use crate::metrics::{Counters, Timer};
use crate::streaming::batcher::BatchPolicy;
use crate::streaming::outlier::OutlierConfig;
use crate::streaming::StreamEvent;
use crate::telemetry::{HistId, MetricId, Registry};

use super::codec::{put_f64, put_u64, put_u8, read_section, write_section, Cursor};
use super::kill;
use super::snapshot::{
    self, put_kernel, put_space, quarantine_snapshot, read_snapshot, snapshot_path,
    take_kernel, take_space, write_snapshot, EngineState,
};
use super::wal::{read_records, wal_path, Wal, WalRecord};

/// Durability policy knobs.
#[derive(Clone, Copy, Debug)]
pub struct DurabilityConfig {
    /// Snapshot the engine every this many successful rounds (`>= 1`).
    pub checkpoint_every: u64,
    /// Snapshot generations retained after GC (`>= 1`; keep `>= 2` to
    /// survive a corrupted newest generation).
    pub keep_generations: usize,
}

impl Default for DurabilityConfig {
    fn default() -> Self {
        Self { checkpoint_every: 8, keep_generations: 2 }
    }
}

impl DurabilityConfig {
    fn validate(&self) -> Result<()> {
        if self.checkpoint_every == 0 {
            return Err(Error::Config("checkpoint_every must be >= 1".into()));
        }
        if self.keep_generations == 0 {
            return Err(Error::Config("keep_generations must be >= 1".into()));
        }
        Ok(())
    }
}

/// One shard's durability state: current generation, its open WAL
/// segment, and the checkpoint cadence.
pub struct ShardStore {
    dir: PathBuf,
    shard_id: usize,
    generation: u64,
    rounds_since_checkpoint: u64,
    wal: Wal,
    cfg: DurabilityConfig,
    scratch: Vec<u8>,
    /// Durability metric slots (`snapshots_written`,
    /// `wal_records_appended`, `checkpoints`) plus the WAL-append /
    /// checkpoint latency histograms. `Shard::attach_store` swaps this for
    /// the owning shard's registry so one instance covers the whole shard.
    telemetry: Arc<Registry>,
}

/// The registry slots that constitute a durability view (store writes the
/// first three; recovery scans record the rest).
pub const DURABILITY_IDS: [MetricId; 8] = [
    MetricId::SnapshotsWritten,
    MetricId::WalRecordsAppended,
    MetricId::Checkpoints,
    MetricId::SnapshotFallbacks,
    MetricId::TornTailsTruncated,
    MetricId::WalRecordsReplayed,
    MetricId::WalReplaySkipped,
    MetricId::RecoveredQuarantined,
];

impl ShardStore {
    /// Initialize a shard's durable state: write snapshot generation 1 of
    /// the engine as it stands and open WAL segment 1.
    pub fn create(
        dir: &Path,
        shard_id: usize,
        engine: &Engine,
        epoch: u64,
        high_seq: u64,
        cfg: DurabilityConfig,
    ) -> Result<Self> {
        cfg.validate()?;
        fs::create_dir_all(dir).map_err(|e| Error::persist_io("ShardStore::create", e))?;
        let telemetry = Arc::new(Registry::new());
        write_snapshot(dir, shard_id, &EngineState::capture(engine, 1, epoch, high_seq))?;
        telemetry.inc(MetricId::SnapshotsWritten);
        let wal = Wal::create(dir, shard_id, 1)?;
        Ok(Self {
            dir: dir.to_path_buf(),
            shard_id,
            generation: 1,
            rounds_since_checkpoint: 0,
            wal,
            cfg,
            scratch: Vec::new(),
            telemetry,
        })
    }

    /// Resume a shard's durable state at `generation` after recovery,
    /// taking a fresh checkpoint there (snapshot + empty segment). Using a
    /// generation strictly above every pre-crash one keeps the invariant
    /// that record sequence numbers never run backwards across segment
    /// order, even after a generation fallback.
    pub fn resume(
        dir: &Path,
        shard_id: usize,
        engine: &Engine,
        epoch: u64,
        high_seq: u64,
        generation: u64,
        cfg: DurabilityConfig,
    ) -> Result<Self> {
        cfg.validate()?;
        let mut store = Self {
            dir: dir.to_path_buf(),
            shard_id,
            generation: generation.saturating_sub(1),
            rounds_since_checkpoint: 0,
            wal: Wal::create(dir, shard_id, generation)?,
            cfg,
            scratch: Vec::new(),
            telemetry: Arc::new(Registry::new()),
        };
        // checkpoint() moves generation forward to `generation` and
        // GCs everything the retention window no longer needs
        store.checkpoint(engine, epoch, high_seq)?;
        Ok(store)
    }

    /// Current snapshot generation.
    pub fn generation(&self) -> u64 {
        self.generation
    }

    /// The state directory.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// The store's live metric slots.
    pub fn telemetry(&self) -> &Arc<Registry> {
        &self.telemetry
    }

    /// Record into `reg` from here on, after folding the counts recorded
    /// so far into it (how `Shard::attach_store` unifies the shard's and
    /// its store's slots into one instance).
    pub fn set_telemetry(&mut self, reg: Arc<Registry>) {
        reg.absorb(&self.telemetry);
        self.telemetry = reg;
    }

    /// String-keyed view over the durability slots only (legacy
    /// `counters` field surface; names are unchanged).
    pub fn counters(&self) -> Counters {
        self.telemetry.counters_for(&DURABILITY_IDS)
    }

    /// Write-ahead log one validated event batch (before it is applied).
    pub fn log_batch(&mut self, seq: u64, events: &[StreamEvent]) -> Result<()> {
        let rec = WalRecord::Batch { seq, events: events.to_vec() };
        let t = Timer::start();
        self.wal.append(&rec, &mut self.scratch)?;
        self.telemetry.record_secs(HistId::WalAppendUs, t.elapsed());
        self.telemetry.inc(MetricId::WalRecordsAppended);
        Ok(())
    }

    /// Write-ahead log an outlier-eviction round.
    pub fn log_evict(&mut self, seq: u64) -> Result<()> {
        let t = Timer::start();
        self.wal.append(&WalRecord::Evict { seq }, &mut self.scratch)?;
        self.telemetry.record_secs(HistId::WalAppendUs, t.elapsed());
        self.telemetry.inc(MetricId::WalRecordsAppended);
        Ok(())
    }

    /// Write-ahead log a self-heal refactorization.
    pub fn log_heal(&mut self, seq: u64) -> Result<()> {
        let t = Timer::start();
        self.wal.append(&WalRecord::Heal { seq }, &mut self.scratch)?;
        self.telemetry.record_secs(HistId::WalAppendUs, t.elapsed());
        self.telemetry.inc(MetricId::WalRecordsAppended);
        Ok(())
    }

    /// Called after each successful round: checkpoint when the cadence
    /// says so. Returns whether a checkpoint was taken.
    pub fn maybe_checkpoint(&mut self, engine: &Engine, epoch: u64, high_seq: u64) -> Result<bool> {
        self.rounds_since_checkpoint += 1;
        if self.rounds_since_checkpoint < self.cfg.checkpoint_every {
            return Ok(false);
        }
        self.checkpoint(engine, epoch, high_seq)?;
        Ok(true)
    }

    /// Unconditional checkpoint: snapshot at `generation + 1`, open that
    /// generation's WAL segment, GC what retention no longer needs.
    pub fn checkpoint(&mut self, engine: &Engine, epoch: u64, high_seq: u64) -> Result<()> {
        const CTX: &str = "ShardStore::checkpoint";
        let t = Timer::start();
        let gen = self.generation + 1;
        let state = EngineState::capture(engine, gen, epoch, high_seq);
        write_snapshot(&self.dir, self.shard_id, &state)?;
        self.telemetry.inc(MetricId::SnapshotsWritten);
        if kill::fires(KillPoint::SnapNewSegment) {
            return Err(kill::killed(CTX, KillPoint::SnapNewSegment));
        }
        self.wal = Wal::create(&self.dir, self.shard_id, gen)?;
        self.generation = gen;
        self.rounds_since_checkpoint = 0;
        if kill::fires(KillPoint::SnapGc) {
            return Err(kill::killed(CTX, KillPoint::SnapGc));
        }
        self.gc()?;
        self.telemetry.record_secs(HistId::CheckpointUs, t.elapsed());
        self.telemetry.inc(MetricId::Checkpoints);
        Ok(())
    }

    /// Remove snapshot + WAL generations older than the retention window.
    fn gc(&mut self) -> Result<()> {
        const CTX: &str = "ShardStore::gc";
        let gens = snapshot::list_generations(&self.dir, self.shard_id)?;
        if gens.len() <= self.cfg.keep_generations {
            return Ok(());
        }
        for &g in &gens[..gens.len() - self.cfg.keep_generations] {
            for path in [
                snapshot_path(&self.dir, self.shard_id, g),
                wal_path(&self.dir, self.shard_id, g),
            ] {
                match fs::remove_file(&path) {
                    Ok(()) => {}
                    Err(e) if e.kind() == std::io::ErrorKind::NotFound => {}
                    Err(e) => return Err(Error::persist_io(CTX, e)),
                }
            }
        }
        Ok(())
    }
}

/// Everything [`recover_shard`] digs out of one shard's state directory.
pub struct RecoveredShard {
    /// The newest snapshot that decoded cleanly.
    pub state: EngineState,
    /// WAL records from `state.generation` forward, ascending segment
    /// order (replay candidates; the applier skips `seq <= epoch`).
    pub records: Vec<WalRecord>,
    /// What recovery observed (`snapshot_fallbacks`,
    /// `torn_tails_truncated`).
    pub counters: Counters,
    /// Highest generation seen on disk, valid or not — resume at
    /// `max_generation_seen + 1`.
    pub max_generation_seen: u64,
}

/// Scan one shard's directory: newest valid snapshot + WAL suffix.
pub fn recover_shard(dir: &Path, shard_id: usize) -> Result<RecoveredShard> {
    const CTX: &str = "recover_shard";
    // scan-local registry; the string-keyed RecoveredShard::counters view
    // is frozen from it at the end (recovery is a cold path, but it still
    // keeps string keys off every increment)
    let reg = Registry::new();
    let gens = snapshot::list_generations(dir, shard_id)?;
    if gens.is_empty() {
        return Err(Error::persist_corruption(
            CTX,
            format!("no snapshot generations for shard {shard_id} in {}", dir.display()),
        ));
    }
    let mut max_generation_seen = *gens.last().expect("non-empty");
    let mut state = None;
    for &g in gens.iter().rev() {
        let path = snapshot_path(dir, shard_id, g);
        match read_snapshot(&path) {
            Ok(s) if s.generation == g => {
                state = Some(s);
                break;
            }
            Ok(_) => {
                // a snapshot claiming another generation is misfiled bytes
                reg.inc(MetricId::SnapshotFallbacks);
                quarantine_snapshot(&path)?;
            }
            Err(e) if !e.is_transient() => {
                reg.inc(MetricId::SnapshotFallbacks);
                quarantine_snapshot(&path)?;
            }
            Err(e) => return Err(e),
        }
    }
    let state = state.ok_or_else(|| {
        Error::persist_corruption(
            CTX,
            format!("every snapshot generation of shard {shard_id} is corrupt"),
        )
    })?;

    // WAL segments can outrun the chosen snapshot when the newest snapshot
    // was the corrupt one — replay them all, ascending.
    for g in list_wal_generations(dir, shard_id)? {
        max_generation_seen = max_generation_seen.max(g);
    }
    let mut records = Vec::new();
    for g in state.generation..=max_generation_seen {
        let (mut recs, torn) = read_records(&wal_path(dir, shard_id, g))?;
        if torn {
            reg.inc(MetricId::TornTailsTruncated);
        }
        records.append(&mut recs);
    }
    Ok(RecoveredShard { state, records, counters: reg.counters(), max_generation_seen })
}

/// WAL segment generations present for a shard, ascending.
fn list_wal_generations(dir: &Path, shard_id: usize) -> Result<Vec<u64>> {
    const CTX: &str = "list_wal_generations";
    let prefix = format!("shard-{shard_id}-wal-");
    let mut gens = Vec::new();
    let entries = match fs::read_dir(dir) {
        Ok(e) => e,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(gens),
        Err(e) => return Err(Error::persist_io(CTX, e)),
    };
    for entry in entries {
        let entry = entry.map_err(|e| Error::persist_io(CTX, e))?;
        let name = entry.file_name();
        let Some(name) = name.to_str() else { continue };
        let Some(rest) = name.strip_prefix(&prefix) else { continue };
        let Some(g) = rest.strip_suffix(".log") else { continue };
        if let Ok(g) = g.parse::<u64>() {
            gens.push(g);
        }
    }
    gens.sort_unstable();
    Ok(gens)
}

// ---- router metadata ----

/// Fleet-level recovery metadata: how many shards, how arrivals were
/// placed, the shared round policy, and the durability knobs. Written once
/// by `ShardRouter::make_durable` (atomically, no kill points — it is not
/// on the hot write path) and read first by `ShardRouter::recover`.
#[derive(Clone, Debug)]
pub struct RouterMeta {
    /// Shard count K.
    pub shards: usize,
    /// True for content-hash placement (`Placement::Hash`), false for
    /// round-robin. Stored as a plain bool so the persist layer does not
    /// depend on serve-layer types.
    pub hash_placement: bool,
    /// The per-shard round policy.
    pub base: CoordinatorConfig,
    /// Durability knobs to resume with.
    pub durability: DurabilityConfig,
}

const META_MAGIC: &[u8; 8] = b"MIKRRMET";
const META_VERSION: u32 = 1;
const SEC_ROUTER: u32 = 1;

/// The metadata file's path.
pub fn meta_path(dir: &Path) -> PathBuf {
    dir.join("router.meta")
}

/// Atomically write the router metadata file.
pub fn write_meta(dir: &Path, meta: &RouterMeta) -> Result<()> {
    const CTX: &str = "write_meta";
    fs::create_dir_all(dir).map_err(|e| Error::persist_io(CTX, e))?;
    let mut out = Vec::new();
    out.extend_from_slice(META_MAGIC);
    super::codec::put_u32(&mut out, META_VERSION);
    let mut p = Vec::new();
    put_u64(&mut p, meta.shards as u64);
    put_u8(&mut p, meta.hash_placement as u8);
    put_u64(&mut p, meta.durability.checkpoint_every);
    put_u64(&mut p, meta.durability.keep_generations as u64);
    put_kernel(&mut p, &meta.base.kernel);
    put_f64(&mut p, meta.base.ridge);
    match meta.base.space {
        None => put_u8(&mut p, 0),
        Some(s) => {
            put_u8(&mut p, 1);
            put_space(&mut p, s);
        }
    }
    put_u64(&mut p, meta.base.batch.max_batch as u64);
    put_u64(&mut p, meta.base.batch.max_wait.as_nanos() as u64);
    match &meta.base.outlier {
        None => {
            put_u8(&mut p, 0);
            put_f64(&mut p, 0.0);
            put_u64(&mut p, 0);
        }
        Some(o) => {
            put_u8(&mut p, 1);
            put_f64(&mut p, o.z_threshold);
            put_u64(&mut p, o.max_removals as u64);
        }
    }
    put_u8(&mut p, meta.base.with_uncertainty as u8);
    put_u8(&mut p, meta.base.snapshot_rollback as u8);
    match meta.base.fold_eps {
        None => {
            put_u8(&mut p, 0);
            put_f64(&mut p, 0.0);
        }
        Some(eps) => {
            put_u8(&mut p, 1);
            put_f64(&mut p, eps);
        }
    }
    write_section(&mut out, SEC_ROUTER, &p);

    let final_path = meta_path(dir);
    let tmp_path = dir.join("router.meta.tmp");
    {
        use std::io::Write as _;
        let mut f = fs::File::create(&tmp_path).map_err(|e| Error::persist_io(CTX, e))?;
        f.write_all(&out).map_err(|e| Error::persist_io(CTX, e))?;
        f.sync_all().map_err(|e| Error::persist_io(CTX, e))?;
    }
    fs::rename(&tmp_path, &final_path).map_err(|e| Error::persist_io(CTX, e))?;
    snapshot::sync_dir(dir).map_err(|e| Error::persist_io(CTX, e))?;
    Ok(())
}

/// Read and verify the router metadata file.
pub fn read_meta(dir: &Path) -> Result<RouterMeta> {
    const CTX: &str = "read_meta";
    let corrupt = |d: String| Error::persist_corruption(CTX, d);
    let bytes = fs::read(meta_path(dir)).map_err(|e| Error::persist_io(CTX, e))?;
    let mut cur = Cursor::new(&bytes, CTX);
    let magic = cur.take_bytes(META_MAGIC.len())?;
    if magic != META_MAGIC {
        return Err(corrupt(format!("bad magic {magic:02x?}")));
    }
    let version = cur.take_u32()?;
    if version != META_VERSION {
        return Err(corrupt(format!("unsupported version {version}")));
    }
    let (tag, payload) = read_section(&mut cur, CTX)?;
    if tag != SEC_ROUTER {
        return Err(corrupt(format!("unexpected section {tag:#x}")));
    }
    let mut pc = Cursor::new(payload, CTX);
    let shards = pc.take_len()?;
    let hash_placement = pc.take_u8()? != 0;
    let checkpoint_every = pc.take_u64()?;
    let keep_generations = pc.take_len()?;
    let kernel = take_kernel(&mut pc)?;
    let ridge = pc.take_f64()?;
    let space = match pc.take_u8()? {
        0 => None,
        1 => Some(take_space(&mut pc)?),
        b => return Err(corrupt(format!("bad space flag {b}"))),
    };
    let max_batch = pc.take_len()?;
    let max_wait = std::time::Duration::from_nanos(pc.take_u64()?);
    let outlier = {
        let flag = pc.take_u8()?;
        let z_threshold = pc.take_f64()?;
        let max_removals = pc.take_len()?;
        match flag {
            0 => None,
            1 => Some(OutlierConfig { z_threshold, max_removals }),
            b => return Err(corrupt(format!("bad outlier flag {b}"))),
        }
    };
    let with_uncertainty = pc.take_u8()? != 0;
    let snapshot_rollback = pc.take_u8()? != 0;
    let fold_eps = {
        let flag = pc.take_u8()?;
        let eps = pc.take_f64()?;
        match flag {
            0 => None,
            1 => Some(eps),
            b => return Err(corrupt(format!("bad fold flag {b}"))),
        }
    };
    if !pc.is_empty() {
        return Err(corrupt("trailing bytes in router section".into()));
    }
    Ok(RouterMeta {
        shards,
        hash_placement,
        base: CoordinatorConfig {
            kernel,
            ridge,
            space,
            batch: BatchPolicy { max_batch, max_wait },
            outlier,
            with_uncertainty,
            snapshot_rollback,
            fold_eps,
        },
        durability: DurabilityConfig { checkpoint_every, keep_generations },
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Space;
    use crate::data::synth;
    use crate::kernels::Kernel;
    use crate::testutil::ScratchDir;

    fn small_engine(seed: u64) -> Engine {
        let d = synth::ecg_like(24, 4, seed);
        Engine::fit(&d.x, &d.y, &Kernel::poly(2, 1.0), 0.5, Space::Intrinsic, false).unwrap()
    }

    #[test]
    fn checkpoint_cadence_rotates_generations_and_gcs() {
        let dir = ScratchDir::new("store-cadence");
        let e = small_engine(31);
        let cfg = DurabilityConfig { checkpoint_every: 2, keep_generations: 2 };
        let mut store = ShardStore::create(dir.path(), 0, &e, 0, 0, cfg).unwrap();
        assert_eq!(store.generation(), 1);
        let ev = vec![StreamEvent::single(vec![0.0; 4], 0.1, 0, 1)];
        for round in 1..=5u64 {
            store.log_batch(round, &ev).unwrap();
            let ck = store.maybe_checkpoint(&e, round, round).unwrap();
            assert_eq!(ck, round % 2 == 0, "round {round}");
        }
        assert_eq!(store.generation(), 3);
        assert_eq!(store.counters().get("snapshots_written"), 3);
        assert_eq!(store.counters().get("wal_records_appended"), 5);
        assert_eq!(store.counters().get("checkpoints"), 2);
        let gens = snapshot::list_generations(dir.path(), 0).unwrap();
        assert_eq!(gens, vec![2, 3], "generation 1 was GCd");
        assert_eq!(list_wal_generations(dir.path(), 0).unwrap(), vec![2, 3]);
        // the open segment holds exactly the post-checkpoint record
        let (recs, torn) = read_records(&wal_path(dir.path(), 0, 3)).unwrap();
        assert!(!torn);
        assert_eq!(recs.len(), 1);
        assert_eq!(recs[0].seq(), 5);
    }

    #[test]
    fn recover_prefers_newest_valid_snapshot() {
        let dir = ScratchDir::new("store-recover");
        let e = small_engine(32);
        let cfg = DurabilityConfig { checkpoint_every: 100, keep_generations: 2 };
        let mut store = ShardStore::create(dir.path(), 0, &e, 0, 0, cfg).unwrap();
        store.checkpoint(&e, 3, 3).unwrap();
        store.log_evict(4).unwrap();
        let rec = recover_shard(dir.path(), 0).unwrap();
        assert_eq!(rec.state.generation, 2);
        assert_eq!(rec.state.high_seq, 3);
        assert_eq!(rec.max_generation_seen, 2);
        assert_eq!(rec.records.len(), 1);
        assert_eq!(rec.records[0].seq(), 4);
        assert_eq!(rec.counters.get("snapshot_fallbacks"), 0);
        let rebuilt = rec.state.rebuild().unwrap();
        assert_eq!(rebuilt.n_samples(), e.n_samples());
    }

    #[test]
    fn corrupt_newest_generation_falls_back_and_replays_older_segment() {
        let dir = ScratchDir::new("store-fallback");
        let e = small_engine(33);
        let cfg = DurabilityConfig { checkpoint_every: 100, keep_generations: 2 };
        let mut store = ShardStore::create(dir.path(), 0, &e, 0, 0, cfg).unwrap();
        store.log_evict(1).unwrap();
        store.log_evict(2).unwrap();
        store.checkpoint(&e, 2, 2).unwrap();
        store.log_evict(3).unwrap();
        // flip one byte inside snapshot generation 2
        let snap2 = snapshot_path(dir.path(), 0, 2);
        let mut bytes = fs::read(&snap2).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x01;
        fs::write(&snap2, &bytes).unwrap();
        let rec = recover_shard(dir.path(), 0).unwrap();
        assert_eq!(rec.state.generation, 1, "fell back one generation");
        assert_eq!(rec.counters.get("snapshot_fallbacks"), 1);
        assert_eq!(rec.max_generation_seen, 2);
        // the longer suffix: both segments replay (seqs 1, 2 from segment
        // 1 and seq 3 from segment 2)
        assert_eq!(
            rec.records.iter().map(WalRecord::seq).collect::<Vec<_>>(),
            vec![1, 2, 3]
        );
        // the corrupt file is quarantined out of the listing
        assert_eq!(snapshot::list_generations(dir.path(), 0).unwrap(), vec![1]);
        assert!(fs::metadata(snap2.with_extension("snap.corrupt")).is_ok());
    }

    #[test]
    fn resume_checkpoints_above_every_seen_generation() {
        let dir = ScratchDir::new("store-resume");
        let e = small_engine(34);
        let cfg = DurabilityConfig::default();
        let mut store = ShardStore::create(dir.path(), 0, &e, 0, 0, cfg).unwrap();
        store.checkpoint(&e, 1, 1).unwrap();
        drop(store);
        let rec = recover_shard(dir.path(), 0).unwrap();
        let store =
            ShardStore::resume(dir.path(), 0, &e, 1, 1, rec.max_generation_seen + 1, cfg).unwrap();
        assert_eq!(store.generation(), 3);
        let gens = snapshot::list_generations(dir.path(), 0).unwrap();
        assert_eq!(*gens.last().unwrap(), 3);
    }

    #[test]
    fn router_meta_round_trips() {
        let dir = ScratchDir::new("store-meta");
        let mut base = CoordinatorConfig::default_for(Kernel::Rbf { gamma: 0.02 });
        base.space = Some(Space::Empirical);
        base.with_uncertainty = true;
        base.snapshot_rollback = true;
        base.fold_eps = Some(1e-9);
        base.batch.max_batch = 7;
        base.batch.max_wait = std::time::Duration::from_millis(21);
        let meta = RouterMeta {
            shards: 5,
            hash_placement: true,
            base,
            durability: DurabilityConfig { checkpoint_every: 3, keep_generations: 4 },
        };
        write_meta(dir.path(), &meta).unwrap();
        let got = read_meta(dir.path()).unwrap();
        assert_eq!(got.shards, 5);
        assert!(got.hash_placement);
        assert_eq!(got.durability.checkpoint_every, 3);
        assert_eq!(got.durability.keep_generations, 4);
        assert_eq!(got.base.kernel, Kernel::Rbf { gamma: 0.02 });
        assert_eq!(got.base.space, Some(Space::Empirical));
        assert_eq!(got.base.batch.max_batch, 7);
        assert_eq!(got.base.batch.max_wait, std::time::Duration::from_millis(21));
        let o = got.base.outlier.expect("outlier config survives");
        assert_eq!(o.z_threshold, 4.0);
        assert_eq!(o.max_removals, 2);
        assert!(got.base.with_uncertainty);
        assert!(got.base.snapshot_rollback);
        assert_eq!(got.base.fold_eps, Some(1e-9));
        // corruption is rejected
        let path = meta_path(dir.path());
        let mut bytes = fs::read(&path).unwrap();
        let last = bytes.len() - 10;
        bytes[last] ^= 0x80;
        fs::write(&path, &bytes).unwrap();
        assert!(read_meta(dir.path()).unwrap_err().to_string().contains("corruption"));
    }
}
