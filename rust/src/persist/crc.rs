//! CRC-32 (IEEE 802.3, reflected, polynomial `0xEDB88320`) — the framing
//! checksum for every snapshot section and WAL record.
//!
//! Table-driven and dependency-free; the table is built in a `const fn` so
//! it lives in rodata. The IEEE variant is the one `zlib`/`gzip`/ethernet
//! use, which makes the on-disk files checkable with standard tooling
//! (`python3 -c 'import zlib; ...'`) during an incident.

/// Reflected IEEE polynomial.
const POLY: u32 = 0xEDB8_8320;

const fn build_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { (c >> 1) ^ POLY } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static TABLE: [u32; 256] = build_table();

/// Incremental CRC-32 state (for checksumming a frame in pieces without
/// concatenating buffers).
#[derive(Clone, Debug)]
pub struct Crc32 {
    state: u32,
}

impl Default for Crc32 {
    fn default() -> Self {
        Self::new()
    }
}

impl Crc32 {
    /// Fresh checksum state.
    pub fn new() -> Self {
        Self { state: 0xFFFF_FFFF }
    }

    /// Feed bytes.
    pub fn update(&mut self, bytes: &[u8]) {
        let mut c = self.state;
        for &b in bytes {
            c = (c >> 8) ^ TABLE[((c ^ b as u32) & 0xFF) as usize];
        }
        self.state = c;
    }

    /// Final checksum value.
    pub fn finish(&self) -> u32 {
        self.state ^ 0xFFFF_FFFF
    }
}

/// One-shot CRC-32 of a byte slice.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = Crc32::new();
    c.update(bytes);
    c.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // the canonical IEEE check value
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"), 0x414F_A339);
    }

    #[test]
    fn incremental_matches_one_shot() {
        let data = b"incremental == one-shot";
        let mut c = Crc32::new();
        c.update(&data[..7]);
        c.update(&data[7..]);
        assert_eq!(c.finish(), crc32(data));
    }

    #[test]
    fn single_bit_flip_detected() {
        let mut data = vec![0u8; 64];
        data[17] = 0x5A;
        let base = crc32(&data);
        for byte in 0..64 {
            for bit in 0..8 {
                data[byte] ^= 1 << bit;
                assert_ne!(crc32(&data), base, "flip at byte {byte} bit {bit} undetected");
                data[byte] ^= 1 << bit;
            }
        }
    }
}
