//! Hot-path flight recorder: a fixed-capacity ring buffer of structured
//! POD span events.
//!
//! Each owner thread (a shard's writer, the network reactor, the
//! supervisor) keeps its own [`FlightRecorder`] — single-writer, so
//! recording is a plain array store: stamp a monotonic microsecond
//! offset, write a [`SpanEvent`], advance the cursor. No locks, no
//! allocation after construction (the buffer is pre-reserved; asserted
//! in `rust/tests/alloc_count.rs`), and old events are overwritten once
//! the capacity wraps — the recorder always holds the *last* `cap`
//! events, which is exactly the window a post-mortem wants.
//!
//! Dumps are taken automatically at failure boundaries: the supervisor
//! snapshots a shard's recorder the moment it quarantines it
//! (`ShardSupervisor::flight_dumps`), and `ShardRouter::recover` ships
//! one per recovered shard (`ShardRouter::recovery_flight_dumps`), so
//! the event trail leading into a failure survives the failure. The
//! network reactor's recorder tail also rides along in every `MKTL`
//! stats frame.

use std::time::Instant;

/// What a span event marks. POD (`u8` on the wire); the `a`/`b` payload
/// words of the owning [`SpanEvent`] are kind-specific (row counts,
/// shard ids, microsecond durations, sequence numbers).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum SpanKind {
    /// A shard update round began (`a` = batch rows).
    RoundStart = 0,
    /// A shard update round committed (`a` = added, `b` = round µs).
    RoundEnd,
    /// A flush was invoked (`a` = queued events).
    Flush,
    /// A WAL record was appended (`a` = seq, `b` = append µs).
    WalAppend,
    /// The inc/dec engine update ran (`a` = added, `b` = µs).
    IncDec,
    /// An engine snapshot was published (`a` = epoch, `b` = µs).
    Publish,
    /// A failed round was rolled back (`a` = batch rows).
    Rollback,
    /// A health probe ran (`a` = residual picounits, `b` = breaches).
    Probe,
    /// A flush was retried in place (`a` = shard, `b` = attempt).
    Retry,
    /// A shard or batch was quarantined (`a` = shard, `b` = seq).
    Quarantine,
    /// A self-heal refactorization ran (`a` = shard).
    Heal,
    /// A checkpoint rotated the WAL segment (`a` = generation, `b` = µs).
    Checkpoint,
    /// Recovery rebuilt a shard (`a` = shard, `b` = replayed records).
    Recover,
    /// A micro-batch window executed (`a` = rows, `b` = µs).
    WindowExec,
    /// A request was shed by admission control (`a` = request id).
    Shed,
    /// A connection was accepted (`a` = slot).
    Accept,
    /// A connection was closed (`a` = slot).
    ConnClosed,
    /// A frame was rejected as corrupt/oversize (`a` = slot).
    ProtocolError,
}

impl SpanKind {
    /// Every kind, index-ordered (`ALL[i] as usize == i`).
    pub const ALL: [SpanKind; 18] = [
        SpanKind::RoundStart,
        SpanKind::RoundEnd,
        SpanKind::Flush,
        SpanKind::WalAppend,
        SpanKind::IncDec,
        SpanKind::Publish,
        SpanKind::Rollback,
        SpanKind::Probe,
        SpanKind::Retry,
        SpanKind::Quarantine,
        SpanKind::Heal,
        SpanKind::Checkpoint,
        SpanKind::Recover,
        SpanKind::WindowExec,
        SpanKind::Shed,
        SpanKind::Accept,
        SpanKind::ConnClosed,
        SpanKind::ProtocolError,
    ];

    /// Stable lowercase label.
    pub fn name(self) -> &'static str {
        match self {
            SpanKind::RoundStart => "round_start",
            SpanKind::RoundEnd => "round_end",
            SpanKind::Flush => "flush",
            SpanKind::WalAppend => "wal_append",
            SpanKind::IncDec => "inc_dec",
            SpanKind::Publish => "publish",
            SpanKind::Rollback => "rollback",
            SpanKind::Probe => "probe",
            SpanKind::Retry => "retry",
            SpanKind::Quarantine => "quarantine",
            SpanKind::Heal => "heal",
            SpanKind::Checkpoint => "checkpoint",
            SpanKind::Recover => "recover",
            SpanKind::WindowExec => "window_exec",
            SpanKind::Shed => "shed",
            SpanKind::Accept => "accept",
            SpanKind::ConnClosed => "conn_closed",
            SpanKind::ProtocolError => "protocol_error",
        }
    }

    /// Decode a wire byte (`None` = unknown kind, i.e. corruption).
    pub fn from_u8(v: u8) -> Option<SpanKind> {
        Self::ALL.get(v as usize).copied()
    }
}

/// One recorded span: a monotonic timestamp (µs since the recorder was
/// built), a kind, and two kind-specific payload words. 25 bytes on the
/// wire, `Copy` in memory — recording is a struct store, nothing more.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SpanEvent {
    /// Microseconds since the owning recorder's epoch (monotonic clock).
    pub t_us: u64,
    /// What happened.
    pub kind: SpanKind,
    /// First payload word (see [`SpanKind`] docs).
    pub a: u64,
    /// Second payload word.
    pub b: u64,
}

/// Default ring capacity: enough for the event trail of several rounds
/// without ever exceeding ~6 KiB per owner.
pub const DEFAULT_RECORDER_CAPACITY: usize = 256;

/// Single-writer fixed-capacity ring buffer of [`SpanEvent`]s.
#[derive(Clone, Debug)]
pub struct FlightRecorder {
    /// Ring storage, pre-reserved to `cap` (push never reallocates).
    events: Vec<SpanEvent>,
    cap: usize,
    /// Total events ever recorded; `next % cap` is the overwrite slot.
    next: u64,
    epoch: Instant,
}

impl Default for FlightRecorder {
    fn default() -> Self {
        Self::new(DEFAULT_RECORDER_CAPACITY)
    }
}

impl FlightRecorder {
    /// A recorder holding the last `cap` events (`cap >= 1` enforced).
    pub fn new(cap: usize) -> Self {
        let cap = cap.max(1);
        Self {
            events: Vec::with_capacity(cap),
            cap,
            next: 0,
            epoch: Instant::now(),
        }
    }

    /// Record one span. O(1), allocation-free once constructed.
    #[inline]
    pub fn record(&mut self, kind: SpanKind, a: u64, b: u64) {
        let ev = SpanEvent {
            t_us: self.epoch.elapsed().as_micros() as u64,
            kind,
            a,
            b,
        };
        let slot = (self.next % self.cap as u64) as usize;
        if self.events.len() < self.cap {
            self.events.push(ev);
        } else {
            self.events[slot] = ev;
        }
        self.next += 1;
    }

    /// Events currently held (≤ capacity).
    pub fn len(&self) -> usize {
        self.events.len()
    }

    /// True before the first record.
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Ring capacity.
    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Total events ever recorded (including overwritten ones).
    pub fn total_recorded(&self) -> u64 {
        self.next
    }

    /// The last `min(n, len)` events in chronological order.
    pub fn tail(&self, n: usize) -> Vec<SpanEvent> {
        let held = self.events.len();
        let take = n.min(held);
        let mut out = Vec::with_capacity(take);
        // oldest held event sits at `next % cap` once the ring wrapped
        let start = if held < self.cap { 0 } else { (self.next % self.cap as u64) as usize };
        for k in (held - take)..held {
            out.push(self.events[(start + k) % held.max(1)]);
        }
        out
    }

    /// Freeze the whole held window into a labeled post-mortem dump.
    pub fn dump(&self, label: impl Into<String>) -> FlightDump {
        FlightDump {
            label: label.into(),
            total_recorded: self.next,
            events: self.tail(self.events.len()),
        }
    }
}

/// A frozen flight-recorder window, labeled with its origin — what the
/// supervisor attaches to a quarantine and `recover` ships per shard.
#[derive(Clone, Debug)]
pub struct FlightDump {
    /// Where the dump came from (e.g. `"shard-2 quarantine"`).
    pub label: String,
    /// Lifetime events recorded by the source (≥ `events.len()`).
    pub total_recorded: u64,
    /// The held window, chronological.
    pub events: Vec<SpanEvent>,
}

impl FlightDump {
    /// Human-readable rendering for logs/post-mortems.
    pub fn render_text(&self) -> String {
        let mut out = format!(
            "flight dump [{}]: {} held of {} recorded\n",
            self.label,
            self.events.len(),
            self.total_recorded
        );
        for e in &self.events {
            out.push_str(&format!(
                "  +{:>9}us {:<15} a={} b={}\n",
                e.t_us,
                e.kind.name(),
                e.a,
                e.b
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn span_kind_table_round_trips() {
        for (i, k) in SpanKind::ALL.iter().enumerate() {
            assert_eq!(*k as usize, i, "{k:?}");
            assert_eq!(SpanKind::from_u8(i as u8), Some(*k));
        }
        assert_eq!(SpanKind::from_u8(SpanKind::ALL.len() as u8), None);
        assert_eq!(SpanKind::from_u8(u8::MAX), None);
    }

    #[test]
    fn ring_holds_the_last_cap_events_in_order() {
        let mut r = FlightRecorder::new(8);
        assert!(r.is_empty());
        for i in 0..20u64 {
            r.record(SpanKind::RoundStart, i, 0);
        }
        assert_eq!(r.len(), 8);
        assert_eq!(r.capacity(), 8);
        assert_eq!(r.total_recorded(), 20);
        let tail = r.tail(8);
        let ids: Vec<u64> = tail.iter().map(|e| e.a).collect();
        assert_eq!(ids, (12..20).collect::<Vec<_>>(), "last 8, chronological");
        // timestamps are monotone
        for w in tail.windows(2) {
            assert!(w[0].t_us <= w[1].t_us);
        }
        // a shorter tail takes the newest end
        let short: Vec<u64> = r.tail(3).iter().map(|e| e.a).collect();
        assert_eq!(short, vec![17, 18, 19]);
    }

    #[test]
    fn unwrapped_tail_and_dump() {
        let mut r = FlightRecorder::new(16);
        r.record(SpanKind::Flush, 5, 0);
        r.record(SpanKind::Quarantine, 1, 42);
        let tail = r.tail(16);
        assert_eq!(tail.len(), 2);
        assert_eq!(tail[1].kind, SpanKind::Quarantine);
        let dump = r.dump("shard-1 quarantine");
        assert_eq!(dump.events.len(), 2);
        assert_eq!(dump.total_recorded, 2);
        let text = dump.render_text();
        assert!(text.contains("shard-1 quarantine"), "{text}");
        assert!(text.contains("quarantine"), "{text}");
        assert!(text.contains("b=42"), "{text}");
    }

    #[test]
    fn capacity_floor_is_one() {
        let mut r = FlightRecorder::new(0);
        assert_eq!(r.capacity(), 1);
        r.record(SpanKind::Shed, 1, 0);
        r.record(SpanKind::Shed, 2, 0);
        assert_eq!(r.len(), 1);
        assert_eq!(r.tail(4)[0].a, 2, "only the newest survives");
    }
}
