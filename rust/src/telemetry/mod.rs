//! Fleet telemetry: the lock-free metrics registry, the hot-path flight
//! recorder, and the snapshot type the `MKTL` wire frame carries.
//!
//! Three pieces, one discipline — observability must cost (almost)
//! nothing on the paths it observes:
//!
//! * [`registry::Registry`] — statically-keyed `AtomicU64` counters,
//!   high-water gauges, and fixed-bucket log₂ histograms. [`MetricId`] /
//!   [`HistId`] enums replace string keys, increments are relaxed
//!   atomics, and the warm path is O(1) and allocation-free (the
//!   `alloc_count.rs` contract covers it). Per-owner registries merge
//!   into one [`TelemetrySnapshot`] fleet view, following the PR 8
//!   durability-counter idiom.
//! * [`trace::FlightRecorder`] — a per-thread fixed-capacity ring of POD
//!   [`SpanEvent`]s (round/WAL/publish/probe/quarantine/...), dumped
//!   automatically at failure boundaries so post-mortems ship with the
//!   failure.
//! * [`TelemetrySnapshot`] — the frozen fleet view: deterministic
//!   canonical encoding (the `MKTL` stats frame payload pulled by
//!   `NetClient::stats`), `render_text` for humans, `write_json` for
//!   machines.
//!
//! The legacy [`crate::metrics::Counters`] stays as the string-keyed
//! aggregation/rendering surface: every owner exposes `counters()`
//! views built from its registry, and hot paths no longer touch the
//! allocating `BTreeMap` (CI greps enforce this outside `metrics/`).

pub mod registry;
pub mod trace;

pub use registry::{
    HistId, HistSnapshot, MetricId, MetricKind, Registry, TelemetrySnapshot, HIST_BUCKETS,
};
pub use trace::{
    FlightDump, FlightRecorder, SpanEvent, SpanKind, DEFAULT_RECORDER_CAPACITY,
};
