//! Lock-free, statically-keyed metrics registry.
//!
//! The serving tier's observability source of truth: one [`Registry`] per
//! owner (shard, router, supervisor, reactor, micro-batch server), each a
//! fixed array of `AtomicU64` slots indexed by the [`MetricId`] /
//! [`HistId`] enums — no string hashing, no `BTreeMap` allocation, no
//! locks. The hot path is a single relaxed `fetch_add` per event, O(1)
//! and allocation-free (asserted in `rust/tests/alloc_count.rs`), so the
//! registry can sit inside the shard round and reactor event loop at a
//! ≤ 3% overhead budget (the `serve/telemetry_overhead` bench gates the
//! measured ratio).
//!
//! Latency/occupancy distributions use fixed log₂ buckets: recording a
//! value bumps bucket `floor(log2(v)) + 1` (bucket 0 holds exact zeros),
//! so a histogram is 64 counters — O(1) memory forever, unlike the raw
//! sample vector the old `LatencyHist` kept. Quantiles come back out of
//! the bucket counts as the covering bucket's upper edge clamped to the
//! observed `[min, max]`, which bounds the relative error at 2× and is
//! exact at the extremes.
//!
//! Aggregation follows the PR 8 durability-counter idiom: per-owner
//! registries `merge` into one [`TelemetrySnapshot`] fleet view
//! (counters sum, max-gauges take the max, histogram buckets add).
//! Snapshots render as text ([`TelemetrySnapshot::render_text`]), as
//! benchlib-style JSON ([`TelemetrySnapshot::write_json`]), and encode
//! to the canonical byte layout the `MKTL` wire frame carries
//! ([`TelemetrySnapshot::encode`] / [`TelemetrySnapshot::decode`]) —
//! deterministic, so two idle pulls off a live server are bitwise equal.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::error::{Error, Result};
use crate::metrics::Counters;
use crate::persist::codec::{put_u32, put_u64, put_u8, Cursor};

use super::trace::{SpanEvent, SpanKind};

/// Statically-keyed counter/gauge slots. The `name()` strings are the
/// legacy `Counters` keys, so registry-backed views render identically
/// to the pre-telemetry string-keyed counters.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u32)]
pub enum MetricId {
    // -- shard round (serve write path) --
    /// Successful update rounds (shard and router both count theirs).
    Rounds = 0,
    /// Samples added across rounds.
    Added,
    /// Samples removed (evictions).
    Removed,
    /// Rounds rolled back after a failed inc/dec.
    Rollbacks,
    /// Near-duplicate inputs folded into multiplicity weights.
    Folded,
    /// Events rejected before staging (shape mismatches).
    Rejected,
    /// Events rejected for non-finite features/targets.
    RejectedNonfinite,
    /// Events dropped by the requeue-vs-drop policy.
    Dropped,
    /// Self-heal refactorizations (shard and supervisor).
    Heals,
    /// Failures forced by the chaos fault plan.
    ChaosForcedFailures,
    /// Engine snapshots published to readers.
    EpochsPublished,
    // -- router --
    /// Events routed to a shard's ingest queue.
    Routed,
    /// Shard errors surfaced by a router round.
    ShardErrors,
    // -- recovery scan --
    /// Corrupt newest snapshots skipped for an older generation.
    SnapshotFallbacks,
    /// WAL tails truncated at a torn record.
    TornTailsTruncated,
    /// WAL records replayed into a recovered engine.
    WalRecordsReplayed,
    /// WAL records skipped as already applied (`seq <= epoch`).
    WalReplaySkipped,
    /// Shards that failed the post-recovery probe and rejoined quarantined.
    RecoveredQuarantined,
    // -- durable store --
    /// Engine snapshots written.
    SnapshotsWritten,
    /// WAL records appended.
    WalRecordsAppended,
    /// Checkpoints taken (snapshot + segment rotation + GC).
    Checkpoints,
    // -- supervisor --
    /// In-place flush retries.
    Retries,
    /// Batches quarantined after the retry budget.
    BatchesQuarantined,
    /// Events inside quarantined batches.
    EventsQuarantined,
    /// Shards marked `Quarantined`.
    ShardsQuarantined,
    /// Quarantined shards brought back to `Healthy`.
    ShardsRecovered,
    /// Probe checks that breached the residual threshold.
    ProbeBreaches,
    /// Probes that escalated to `Critical`.
    ProbeTrips,
    /// Self-heal attempts that failed.
    HealFailures,
    /// Faults injected by the chaos plan.
    FaultsInjected,
    // -- network reactor --
    /// Connections accepted.
    Accepted,
    /// Connections rejected at the `max_conns` cap.
    ConnRejected,
    /// Predict requests shed over the pending budget.
    ShedPredict,
    /// Update frames shed over the bounded queue.
    ShedUpdate,
    /// Predict requests answered.
    PredictsServed,
    /// Update frames admitted to the ingest queue.
    UpdatesAdmitted,
    /// Frames rejected as corrupt/oversize/unknown.
    ProtocolErrors,
    /// Connections closed for an over-cap write buffer.
    SlowReaderClosed,
    /// Micro-batch windows executed.
    Batches,
    /// Requests entering a micro-batch window.
    Requests,
    /// Event-loop poll errors.
    PollErrors,
    // -- high-water gauges (merge takes the max, not the sum) --
    /// Most rows ever pending in one window.
    MaxPendingRows,
    /// Largest micro-batch window executed.
    MaxBatchRows,
}

/// How a slot aggregates across registries.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MetricKind {
    /// Monotone event count: merge by summing.
    Counter,
    /// High-water gauge: merge by taking the max.
    MaxGauge,
}

impl MetricId {
    /// Every id, index-ordered (`ALL[i] as usize == i`).
    pub const ALL: [MetricId; 43] = [
        MetricId::Rounds,
        MetricId::Added,
        MetricId::Removed,
        MetricId::Rollbacks,
        MetricId::Folded,
        MetricId::Rejected,
        MetricId::RejectedNonfinite,
        MetricId::Dropped,
        MetricId::Heals,
        MetricId::ChaosForcedFailures,
        MetricId::EpochsPublished,
        MetricId::Routed,
        MetricId::ShardErrors,
        MetricId::SnapshotFallbacks,
        MetricId::TornTailsTruncated,
        MetricId::WalRecordsReplayed,
        MetricId::WalReplaySkipped,
        MetricId::RecoveredQuarantined,
        MetricId::SnapshotsWritten,
        MetricId::WalRecordsAppended,
        MetricId::Checkpoints,
        MetricId::Retries,
        MetricId::BatchesQuarantined,
        MetricId::EventsQuarantined,
        MetricId::ShardsQuarantined,
        MetricId::ShardsRecovered,
        MetricId::ProbeBreaches,
        MetricId::ProbeTrips,
        MetricId::HealFailures,
        MetricId::FaultsInjected,
        MetricId::Accepted,
        MetricId::ConnRejected,
        MetricId::ShedPredict,
        MetricId::ShedUpdate,
        MetricId::PredictsServed,
        MetricId::UpdatesAdmitted,
        MetricId::ProtocolErrors,
        MetricId::SlowReaderClosed,
        MetricId::Batches,
        MetricId::Requests,
        MetricId::PollErrors,
        MetricId::MaxPendingRows,
        MetricId::MaxBatchRows,
    ];

    /// Number of counter/gauge slots.
    pub const COUNT: usize = Self::ALL.len();

    /// Stable string key — the legacy `Counters` name.
    pub fn name(self) -> &'static str {
        match self {
            MetricId::Rounds => "rounds",
            MetricId::Added => "added",
            MetricId::Removed => "removed",
            MetricId::Rollbacks => "rollbacks",
            MetricId::Folded => "folded",
            MetricId::Rejected => "rejected",
            MetricId::RejectedNonfinite => "rejected_nonfinite",
            MetricId::Dropped => "dropped",
            MetricId::Heals => "heals",
            MetricId::ChaosForcedFailures => "chaos_forced_failures",
            MetricId::EpochsPublished => "epochs_published",
            MetricId::Routed => "routed",
            MetricId::ShardErrors => "shard_errors",
            MetricId::SnapshotFallbacks => "snapshot_fallbacks",
            MetricId::TornTailsTruncated => "torn_tails_truncated",
            MetricId::WalRecordsReplayed => "wal_records_replayed",
            MetricId::WalReplaySkipped => "wal_replay_skipped",
            MetricId::RecoveredQuarantined => "recovered_quarantined",
            MetricId::SnapshotsWritten => "snapshots_written",
            MetricId::WalRecordsAppended => "wal_records_appended",
            MetricId::Checkpoints => "checkpoints",
            MetricId::Retries => "retries",
            MetricId::BatchesQuarantined => "batches_quarantined",
            MetricId::EventsQuarantined => "events_quarantined",
            MetricId::ShardsQuarantined => "shards_quarantined",
            MetricId::ShardsRecovered => "shards_recovered",
            MetricId::ProbeBreaches => "probe_breaches",
            MetricId::ProbeTrips => "probe_trips",
            MetricId::HealFailures => "heal_failures",
            MetricId::FaultsInjected => "faults_injected",
            MetricId::Accepted => "accepted",
            MetricId::ConnRejected => "conn_rejected",
            MetricId::ShedPredict => "shed_predict",
            MetricId::ShedUpdate => "shed_update",
            MetricId::PredictsServed => "predicts_served",
            MetricId::UpdatesAdmitted => "updates_admitted",
            MetricId::ProtocolErrors => "protocol_errors",
            MetricId::SlowReaderClosed => "slow_reader_closed",
            MetricId::Batches => "batches",
            MetricId::Requests => "requests",
            MetricId::PollErrors => "poll_errors",
            MetricId::MaxPendingRows => "max_pending_rows",
            MetricId::MaxBatchRows => "max_batch_rows",
        }
    }

    /// The slot's aggregation rule.
    pub fn kind(self) -> MetricKind {
        match self {
            MetricId::MaxPendingRows | MetricId::MaxBatchRows => MetricKind::MaxGauge,
            _ => MetricKind::Counter,
        }
    }

    /// Decode an index (wire/dump paths; `None` = unknown slot).
    pub fn from_index(i: usize) -> Option<MetricId> {
        Self::ALL.get(i).copied()
    }
}

/// Statically-keyed histogram slots. All record `u64` values — timings
/// in whole microseconds (`*_us`), occupancies in rows, residuals in
/// picounits (`residual * 1e12`, so the healthy 1e-14..1e-6 band maps
/// onto distinguishable integer buckets).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u32)]
pub enum HistId {
    /// Whole shard update round, µs.
    RoundLatencyUs = 0,
    /// Round phase: validate/stage/plan-folds, µs.
    PhasePlanUs,
    /// Round phase: write-ahead log append, µs.
    PhaseWalUs,
    /// Round phase: inc/dec engine update, µs.
    PhaseIncDecUs,
    /// Round phase: epoch snapshot publish, µs.
    PhasePublishUs,
    /// Rows per executed micro-batch window.
    WindowOccupancyRows,
    /// `Mean` lane execution, µs.
    LaneMeanUs,
    /// `MeanVar` lane execution, µs.
    LaneMeanVarUs,
    /// `MeanMulti` lane execution, µs.
    LaneMeanMultiUs,
    /// `MeanVarMulti` lane execution, µs.
    LaneMeanVarMultiUs,
    /// One WAL record append, µs.
    WalAppendUs,
    /// One checkpoint (snapshot + rotate + GC), µs.
    CheckpointUs,
    /// Health-probe max residual, picounits.
    ProbeResidualPicos,
}

impl HistId {
    /// Every id, index-ordered (`ALL[i] as usize == i`).
    pub const ALL: [HistId; 13] = [
        HistId::RoundLatencyUs,
        HistId::PhasePlanUs,
        HistId::PhaseWalUs,
        HistId::PhaseIncDecUs,
        HistId::PhasePublishUs,
        HistId::WindowOccupancyRows,
        HistId::LaneMeanUs,
        HistId::LaneMeanVarUs,
        HistId::LaneMeanMultiUs,
        HistId::LaneMeanVarMultiUs,
        HistId::WalAppendUs,
        HistId::CheckpointUs,
        HistId::ProbeResidualPicos,
    ];

    /// Number of histogram slots.
    pub const COUNT: usize = Self::ALL.len();

    /// Stable string key (JSON/text rendering).
    pub fn name(self) -> &'static str {
        match self {
            HistId::RoundLatencyUs => "round_latency_us",
            HistId::PhasePlanUs => "phase_plan_us",
            HistId::PhaseWalUs => "phase_wal_us",
            HistId::PhaseIncDecUs => "phase_incdec_us",
            HistId::PhasePublishUs => "phase_publish_us",
            HistId::WindowOccupancyRows => "window_occupancy_rows",
            HistId::LaneMeanUs => "lane_mean_us",
            HistId::LaneMeanVarUs => "lane_meanvar_us",
            HistId::LaneMeanMultiUs => "lane_mean_multi_us",
            HistId::LaneMeanVarMultiUs => "lane_meanvar_multi_us",
            HistId::WalAppendUs => "wal_append_us",
            HistId::CheckpointUs => "checkpoint_us",
            HistId::ProbeResidualPicos => "probe_residual_picos",
        }
    }

    /// Decode an index (wire/dump paths; `None` = unknown slot).
    pub fn from_index(i: usize) -> Option<HistId> {
        Self::ALL.get(i).copied()
    }
}

/// Number of log₂ buckets per histogram (bucket 0 = exact zeros, bucket
/// `b >= 1` covers `[2^(b-1), 2^b)`; the top bucket absorbs overflow).
pub const HIST_BUCKETS: usize = 64;

#[inline]
fn bucket_of(v: u64) -> usize {
    if v == 0 {
        0
    } else {
        (64 - v.leading_zeros() as usize).min(HIST_BUCKETS - 1)
    }
}

#[inline]
fn bucket_upper(b: usize) -> u64 {
    if b == 0 {
        0
    } else {
        (1u64 << b) - 1
    }
}

/// One histogram's atomic slots.
struct AtomicHist {
    buckets: [AtomicU64; HIST_BUCKETS],
    count: AtomicU64,
    sum: AtomicU64,
    min: AtomicU64,
    max: AtomicU64,
}

impl AtomicHist {
    fn new() -> Self {
        Self {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            min: AtomicU64::new(u64::MAX),
            max: AtomicU64::new(0),
        }
    }

    #[inline]
    fn record(&self, v: u64) {
        self.buckets[bucket_of(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.min.fetch_min(v, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
    }
}

/// The lock-free registry: one `AtomicU64` slot per [`MetricId`], one
/// 64-bucket atomic histogram per [`HistId`]. Shared by `Arc` between
/// the owning writer and any readers (snapshot handles, the wire stats
/// path); every mutation is a relaxed atomic RMW, so `&self` suffices
/// and the hot path never locks or allocates.
pub struct Registry {
    enabled: bool,
    counters: [AtomicU64; MetricId::COUNT],
    hists: [AtomicHist; HistId::COUNT],
}

impl Default for Registry {
    fn default() -> Self {
        Self::new()
    }
}

impl std::fmt::Debug for Registry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Registry").field("enabled", &self.enabled).finish()
    }
}

impl Registry {
    /// A live registry.
    pub fn new() -> Self {
        Self {
            enabled: true,
            counters: std::array::from_fn(|_| AtomicU64::new(0)),
            hists: std::array::from_fn(|_| AtomicHist::new()),
        }
    }

    /// A registry whose recording calls are no-ops — the uninstrumented
    /// baseline for the `serve/telemetry_overhead` bench.
    pub fn disabled() -> Self {
        Self { enabled: false, ..Self::new() }
    }

    /// Whether recording is live.
    pub fn is_enabled(&self) -> bool {
        self.enabled
    }

    /// Add `v` to a counter slot (relaxed, lock-free, allocation-free).
    #[inline]
    pub fn add(&self, id: MetricId, v: u64) {
        if self.enabled {
            self.counters[id as usize].fetch_add(v, Ordering::Relaxed);
        }
    }

    /// Increment a counter slot.
    #[inline]
    pub fn inc(&self, id: MetricId) {
        self.add(id, 1);
    }

    /// Raise a high-water gauge slot to at least `v`.
    #[inline]
    pub fn gauge_max(&self, id: MetricId, v: u64) {
        if self.enabled {
            self.counters[id as usize].fetch_max(v, Ordering::Relaxed);
        }
    }

    /// Read one slot.
    pub fn get(&self, id: MetricId) -> u64 {
        self.counters[id as usize].load(Ordering::Relaxed)
    }

    /// Record a value into a histogram slot.
    #[inline]
    pub fn record_hist(&self, id: HistId, v: u64) {
        if self.enabled {
            self.hists[id as usize].record(v);
        }
    }

    /// Record a duration in seconds into a `*_us` histogram slot.
    #[inline]
    pub fn record_secs(&self, id: HistId, seconds: f64) {
        if self.enabled {
            self.record_hist(id, (seconds * 1e6) as u64);
        }
    }

    /// Fold another registry's counts into this one (counters add,
    /// gauges max, histogram buckets add) — used when an owner adopts a
    /// shared registry and must not lose what it already recorded.
    pub fn absorb(&self, other: &Registry) {
        for id in MetricId::ALL {
            let v = other.counters[id as usize].load(Ordering::Relaxed);
            if v == 0 {
                continue;
            }
            match id.kind() {
                MetricKind::Counter => self.add(id, v),
                MetricKind::MaxGauge => self.gauge_max(id, v),
            }
        }
        for i in 0..HistId::COUNT {
            let (src, dst) = (&other.hists[i], &self.hists[i]);
            let n = src.count.load(Ordering::Relaxed);
            if n == 0 {
                continue;
            }
            for b in 0..HIST_BUCKETS {
                let c = src.buckets[b].load(Ordering::Relaxed);
                if c != 0 {
                    dst.buckets[b].fetch_add(c, Ordering::Relaxed);
                }
            }
            dst.count.fetch_add(n, Ordering::Relaxed);
            dst.sum.fetch_add(src.sum.load(Ordering::Relaxed), Ordering::Relaxed);
            dst.min.fetch_min(src.min.load(Ordering::Relaxed), Ordering::Relaxed);
            dst.max.fetch_max(src.max.load(Ordering::Relaxed), Ordering::Relaxed);
        }
    }

    /// Fold a string-keyed [`Counters`] view into the matching slots
    /// (keys that name no registry metric are ignored) — the bridge for
    /// cold paths that still produce legacy `Counters` values.
    pub fn absorb_counters(&self, c: &Counters) {
        for id in MetricId::ALL {
            let v = c.get(id.name());
            if v == 0 {
                continue;
            }
            match id.kind() {
                MetricKind::Counter => self.add(id, v),
                MetricKind::MaxGauge => self.gauge_max(id, v),
            }
        }
    }

    /// Snapshot into a fleet view (counters sum, gauges max, buckets add).
    pub fn merge_into(&self, snap: &mut TelemetrySnapshot) {
        for id in MetricId::ALL {
            let i = id as usize;
            let v = self.counters[i].load(Ordering::Relaxed);
            match id.kind() {
                MetricKind::Counter => snap.counters[i] += v,
                MetricKind::MaxGauge => snap.counters[i] = snap.counters[i].max(v),
            }
        }
        for i in 0..HistId::COUNT {
            let (src, dst) = (&self.hists[i], &mut snap.hists[i]);
            let n = src.count.load(Ordering::Relaxed);
            if n == 0 {
                continue;
            }
            for b in 0..HIST_BUCKETS {
                dst.buckets[b] += src.buckets[b].load(Ordering::Relaxed);
            }
            dst.count += n;
            dst.sum += src.sum.load(Ordering::Relaxed);
            dst.min = dst.min.min(src.min.load(Ordering::Relaxed));
            dst.max = dst.max.max(src.max.load(Ordering::Relaxed));
        }
    }

    /// Snapshot this registry alone.
    pub fn snapshot(&self) -> TelemetrySnapshot {
        let mut snap = TelemetrySnapshot::new();
        self.merge_into(&mut snap);
        snap
    }

    /// The legacy string-keyed view: every non-zero slot under its
    /// [`MetricId::name`]. `Counters` stays the aggregation/rendering
    /// surface; this registry is where hot paths record.
    pub fn counters(&self) -> Counters {
        let mut out = Counters::default();
        for id in MetricId::ALL {
            let v = self.get(id);
            if v != 0 {
                out.add(id.name(), v);
            }
        }
        out
    }

    /// String-keyed view restricted to `ids` (still skipping zeros).
    pub fn counters_for(&self, ids: &[MetricId]) -> Counters {
        let mut out = Counters::default();
        for &id in ids {
            let v = self.get(id);
            if v != 0 {
                out.add(id.name(), v);
            }
        }
        out
    }
}

/// One histogram, frozen: bucket counts plus exact count/sum/min/max.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct HistSnapshot {
    /// Log₂ bucket counts (see [`HIST_BUCKETS`]).
    pub buckets: [u64; HIST_BUCKETS],
    /// Total recorded values.
    pub count: u64,
    /// Sum of recorded values.
    pub sum: u64,
    /// Smallest recorded value (`u64::MAX` when empty).
    pub min: u64,
    /// Largest recorded value (0 when empty).
    pub max: u64,
}

impl Default for HistSnapshot {
    fn default() -> Self {
        Self { buckets: [0; HIST_BUCKETS], count: 0, sum: 0, min: u64::MAX, max: 0 }
    }
}

impl HistSnapshot {
    /// Quantile from the bucket counts: the covering bucket's upper edge
    /// clamped to the observed `[min, max]` (0 when empty).
    pub fn percentile(&self, p: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = ((p / 100.0) * self.count as f64).ceil().max(1.0) as u64;
        let rank = rank.min(self.count);
        let mut cum = 0u64;
        for (b, &c) in self.buckets.iter().enumerate() {
            cum += c;
            if cum >= rank {
                return bucket_upper(b).clamp(self.min, self.max);
            }
        }
        self.max
    }

    /// Median.
    pub fn p50(&self) -> u64 {
        self.percentile(50.0)
    }

    /// 99th percentile.
    pub fn p99(&self) -> u64 {
        self.percentile(99.0)
    }

    /// Mean of recorded values (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }
}

/// A frozen fleet view: every counter/gauge slot, every histogram, and
/// the flight-recorder tail that shipped with it. This is both the
/// in-process aggregation product (`ShardRouter::telemetry`) and the
/// `MKTL` wire payload.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TelemetrySnapshot {
    /// Counter/gauge values, indexed by `MetricId as usize`.
    pub counters: [u64; MetricId::COUNT],
    /// Histograms, indexed by `HistId as usize`.
    pub hists: [HistSnapshot; HistId::COUNT],
    /// Flight-recorder tail (chronological; empty for in-process views).
    pub spans: Vec<SpanEvent>,
}

impl Default for TelemetrySnapshot {
    fn default() -> Self {
        Self::new()
    }
}

impl TelemetrySnapshot {
    /// An empty snapshot.
    pub fn new() -> Self {
        Self {
            counters: [0; MetricId::COUNT],
            hists: std::array::from_fn(|_| HistSnapshot::default()),
            spans: Vec::new(),
        }
    }

    /// Read one counter/gauge slot.
    pub fn counter(&self, id: MetricId) -> u64 {
        self.counters[id as usize]
    }

    /// Read one histogram.
    pub fn hist(&self, id: HistId) -> &HistSnapshot {
        &self.hists[id as usize]
    }

    /// Merge another snapshot (counters sum, gauges max, buckets add;
    /// spans concatenate).
    pub fn merge(&mut self, other: &TelemetrySnapshot) {
        for id in MetricId::ALL {
            let i = id as usize;
            match id.kind() {
                MetricKind::Counter => self.counters[i] += other.counters[i],
                MetricKind::MaxGauge => {
                    self.counters[i] = self.counters[i].max(other.counters[i])
                }
            }
        }
        for i in 0..HistId::COUNT {
            let (src, dst) = (&other.hists[i], &mut self.hists[i]);
            if src.count == 0 {
                continue;
            }
            for b in 0..HIST_BUCKETS {
                dst.buckets[b] += src.buckets[b];
            }
            dst.count += src.count;
            dst.sum += src.sum;
            dst.min = dst.min.min(src.min);
            dst.max = dst.max.max(src.max);
        }
        self.spans.extend_from_slice(&other.spans);
    }

    /// The legacy string-keyed view of the counter slots.
    pub fn to_counters(&self) -> Counters {
        let mut out = Counters::default();
        for id in MetricId::ALL {
            let v = self.counter(id);
            if v != 0 {
                out.add(id.name(), v);
            }
        }
        out
    }

    // ---- canonical byte layout (the MKTL payload) ----
    //
    // [n_counters u32] then per non-zero slot, ascending: [id u32][v u64]
    // [n_hists u32]    then per non-empty hist, ascending:
    //                  [id u32][count u64][sum u64][min u64][max u64]
    //                  [n_buckets u32] then per non-zero bucket,
    //                  ascending: [bucket u8][count u64]
    // [n_spans u32]    then per span: [t_us u64][kind u8][a u64][b u64]
    //
    // Zero slots are skipped and ordering is fixed, so the encoding of a
    // given snapshot is unique — two idle pulls are bitwise identical.

    /// Append the canonical encoding to `out`.
    pub fn encode(&self, out: &mut Vec<u8>) {
        let nonzero = self.counters.iter().filter(|&&v| v != 0).count();
        put_u32(out, nonzero as u32);
        for (i, &v) in self.counters.iter().enumerate() {
            if v != 0 {
                put_u32(out, i as u32);
                put_u64(out, v);
            }
        }
        let live = self.hists.iter().filter(|h| h.count != 0).count();
        put_u32(out, live as u32);
        for (i, h) in self.hists.iter().enumerate() {
            if h.count == 0 {
                continue;
            }
            put_u32(out, i as u32);
            put_u64(out, h.count);
            put_u64(out, h.sum);
            put_u64(out, h.min);
            put_u64(out, h.max);
            let nb = h.buckets.iter().filter(|&&c| c != 0).count();
            put_u32(out, nb as u32);
            for (b, &c) in h.buckets.iter().enumerate() {
                if c != 0 {
                    put_u8(out, b as u8);
                    put_u64(out, c);
                }
            }
        }
        put_u32(out, self.spans.len() as u32);
        for s in &self.spans {
            put_u64(out, s.t_us);
            put_u8(out, s.kind as u8);
            put_u64(out, s.a);
            put_u64(out, s.b);
        }
    }

    /// Decode the canonical layout. Strict: unknown ids/kinds, non-
    /// ascending order, zero entries, or bucket/count mismatches are all
    /// corruption — a hostile payload must never build a half-trusted
    /// snapshot.
    pub fn decode(cur: &mut Cursor<'_>, ctx: &'static str) -> Result<Self> {
        let corrupt = |d: String| Error::persist_corruption(ctx, d);
        let mut snap = TelemetrySnapshot::new();
        let nc = cur.take_u32()? as usize;
        if nc > MetricId::COUNT {
            return Err(corrupt(format!("{nc} counter slots > {}", MetricId::COUNT)));
        }
        let mut prev: Option<usize> = None;
        for _ in 0..nc {
            let i = cur.take_u32()? as usize;
            if MetricId::from_index(i).is_none() {
                return Err(corrupt(format!("unknown metric id {i}")));
            }
            if prev.is_some_and(|p| i <= p) {
                return Err(corrupt(format!("metric id {i} out of order")));
            }
            prev = Some(i);
            let v = cur.take_u64()?;
            if v == 0 {
                return Err(corrupt(format!("explicit zero for metric id {i}")));
            }
            snap.counters[i] = v;
        }
        let nh = cur.take_u32()? as usize;
        if nh > HistId::COUNT {
            return Err(corrupt(format!("{nh} hist slots > {}", HistId::COUNT)));
        }
        let mut prev: Option<usize> = None;
        for _ in 0..nh {
            let i = cur.take_u32()? as usize;
            if HistId::from_index(i).is_none() {
                return Err(corrupt(format!("unknown hist id {i}")));
            }
            if prev.is_some_and(|p| i <= p) {
                return Err(corrupt(format!("hist id {i} out of order")));
            }
            prev = Some(i);
            let h = &mut snap.hists[i];
            h.count = cur.take_u64()?;
            h.sum = cur.take_u64()?;
            h.min = cur.take_u64()?;
            h.max = cur.take_u64()?;
            if h.count == 0 || h.min > h.max {
                return Err(corrupt(format!("hist {i} bad count/min/max")));
            }
            let nb = cur.take_u32()? as usize;
            if nb > HIST_BUCKETS {
                return Err(corrupt(format!("{nb} buckets > {HIST_BUCKETS}")));
            }
            let mut prev_b: Option<usize> = None;
            let mut total = 0u64;
            for _ in 0..nb {
                let b = cur.take_u8()? as usize;
                if b >= HIST_BUCKETS {
                    return Err(corrupt(format!("bucket {b} out of range")));
                }
                if prev_b.is_some_and(|p| b <= p) {
                    return Err(corrupt(format!("bucket {b} out of order")));
                }
                prev_b = Some(b);
                let c = cur.take_u64()?;
                if c == 0 {
                    return Err(corrupt(format!("explicit zero bucket {b}")));
                }
                h.buckets[b] = c;
                total = total.checked_add(c).ok_or_else(|| {
                    Error::persist_corruption(ctx, "bucket counts overflow".into())
                })?;
            }
            if total != h.count {
                return Err(corrupt(format!(
                    "hist {i} bucket sum {total} != count {}",
                    h.count
                )));
            }
        }
        let ns = cur.take_u32()? as usize;
        // a hostile count cannot drive allocation: reserve is capped and
        // each span consumes 25 payload bytes, so an inflated count hits
        // the cursor's truncation error within one iteration
        snap.spans.reserve(ns.min(4096));
        for _ in 0..ns {
            let t_us = cur.take_u64()?;
            let kind = cur.take_u8()?;
            let kind = SpanKind::from_u8(kind)
                .ok_or_else(|| corrupt(format!("unknown span kind {kind}")))?;
            let a = cur.take_u64()?;
            let b = cur.take_u64()?;
            snap.spans.push(SpanEvent { t_us, kind, a, b });
        }
        Ok(snap)
    }

    /// Human-readable multi-line rendering (counters, histogram
    /// quantiles, span tail).
    pub fn render_text(&self) -> String {
        let mut out = String::from("counters:\n");
        for id in MetricId::ALL {
            let v = self.counter(id);
            if v != 0 {
                out.push_str(&format!("  {:<22} {v}\n", id.name()));
            }
        }
        out.push_str("histograms:\n");
        for id in HistId::ALL {
            let h = self.hist(id);
            if h.count != 0 {
                out.push_str(&format!(
                    "  {:<22} n={} mean={:.1} p50={} p99={} max={}\n",
                    id.name(),
                    h.count,
                    h.mean(),
                    h.p50(),
                    h.p99(),
                    h.max
                ));
            }
        }
        if !self.spans.is_empty() {
            out.push_str(&format!("span tail ({} events):\n", self.spans.len()));
            for s in &self.spans {
                out.push_str(&format!(
                    "  +{:>9}us {:<15} a={} b={}\n",
                    s.t_us,
                    s.kind.name(),
                    s.a,
                    s.b
                ));
            }
        }
        out
    }

    /// Machine-readable JSON (benchlib idiom: hand-rolled writer, static
    /// keys, no escaping needed).
    pub fn write_json(&self, out: &mut String) {
        out.push_str("{\n  \"counters\": {");
        let mut first = true;
        for id in MetricId::ALL {
            let v = self.counter(id);
            if v != 0 {
                if !first {
                    out.push(',');
                }
                first = false;
                out.push_str(&format!("\n    \"{}\": {v}", id.name()));
            }
        }
        out.push_str("\n  },\n  \"hists\": {");
        let mut first = true;
        for id in HistId::ALL {
            let h = self.hist(id);
            if h.count == 0 {
                continue;
            }
            if !first {
                out.push(',');
            }
            first = false;
            out.push_str(&format!(
                "\n    \"{}\": {{\"count\": {}, \"mean\": {:.3}, \"p50\": {}, \
                 \"p99\": {}, \"min\": {}, \"max\": {}}}",
                id.name(),
                h.count,
                h.mean(),
                h.p50(),
                h.p99(),
                h.min,
                h.max
            ));
        }
        out.push_str("\n  },\n  \"spans\": [");
        for (i, s) in self.spans.iter().enumerate() {
            if i != 0 {
                out.push(',');
            }
            out.push_str(&format!(
                "\n    {{\"t_us\": {}, \"kind\": \"{}\", \"a\": {}, \"b\": {}}}",
                s.t_us,
                s.kind.name(),
                s.a,
                s.b
            ));
        }
        out.push_str("\n  ]\n}\n");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn id_tables_are_index_ordered() {
        for (i, id) in MetricId::ALL.iter().enumerate() {
            assert_eq!(*id as usize, i, "{id:?}");
            assert_eq!(MetricId::from_index(i), Some(*id));
        }
        for (i, id) in HistId::ALL.iter().enumerate() {
            assert_eq!(*id as usize, i, "{id:?}");
            assert_eq!(HistId::from_index(i), Some(*id));
        }
        assert_eq!(MetricId::from_index(MetricId::COUNT), None);
        assert_eq!(HistId::from_index(HistId::COUNT), None);
        // names are unique (they key the Counters compat view)
        let mut names: Vec<&str> = MetricId::ALL.iter().map(|i| i.name()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), MetricId::COUNT);
    }

    #[test]
    fn buckets_cover_the_u64_range() {
        assert_eq!(bucket_of(0), 0);
        assert_eq!(bucket_of(1), 1);
        assert_eq!(bucket_of(2), 2);
        assert_eq!(bucket_of(3), 2);
        assert_eq!(bucket_of(4), 3);
        assert_eq!(bucket_of(u64::MAX), HIST_BUCKETS - 1);
        for b in 1..HIST_BUCKETS - 1 {
            // bucket b holds exactly [2^(b-1), 2^b)
            assert_eq!(bucket_of(1u64 << (b - 1)), b);
            assert_eq!(bucket_of((1u64 << b) - 1), b);
        }
    }

    #[test]
    fn counters_and_gauges_aggregate_by_kind() {
        let a = Registry::new();
        let b = Registry::new();
        a.add(MetricId::Rounds, 3);
        b.add(MetricId::Rounds, 4);
        a.gauge_max(MetricId::MaxPendingRows, 9);
        b.gauge_max(MetricId::MaxPendingRows, 5);
        let mut snap = a.snapshot();
        b.merge_into(&mut snap);
        assert_eq!(snap.counter(MetricId::Rounds), 7, "counters sum");
        assert_eq!(snap.counter(MetricId::MaxPendingRows), 9, "gauges max");
        // the compat view carries the legacy names
        let c = snap.to_counters();
        assert_eq!(c.get("rounds"), 7);
        assert_eq!(c.get("max_pending_rows"), 9);
        assert_eq!(c.get("missing"), 0);
    }

    #[test]
    fn hist_percentiles_from_buckets() {
        let r = Registry::new();
        for v in 1..=1000u64 {
            r.record_hist(HistId::RoundLatencyUs, v);
        }
        let snap = r.snapshot();
        let h = snap.hist(HistId::RoundLatencyUs);
        assert_eq!(h.count, 1000);
        assert_eq!((h.min, h.max), (1, 1000));
        let p50 = h.p50();
        // true p50 = 500; the covering log2 bucket's upper edge is 511
        assert!((500..=511).contains(&p50), "p50={p50}");
        let p99 = h.p99();
        // true p99 = 990; upper edge 1023 clamps to max 1000
        assert!((990..=1000).contains(&p99), "p99={p99}");
        assert!((h.mean() - 500.5).abs() < 1e-9);
        // an empty histogram reads zero everywhere
        let empty = snap.hist(HistId::CheckpointUs);
        assert_eq!((empty.p50(), empty.p99()), (0, 0));
        assert_eq!(empty.mean(), 0.0);
    }

    #[test]
    fn disabled_registry_records_nothing() {
        let r = Registry::disabled();
        r.inc(MetricId::Rounds);
        r.gauge_max(MetricId::MaxBatchRows, 10);
        r.record_hist(HistId::RoundLatencyUs, 5);
        assert_eq!(r.snapshot(), TelemetrySnapshot::new());
        assert!(!r.is_enabled());
    }

    #[test]
    fn absorb_folds_counts() {
        let keep = Registry::new();
        keep.add(MetricId::SnapshotsWritten, 2);
        keep.record_hist(HistId::CheckpointUs, 100);
        let old = Registry::new();
        old.add(MetricId::SnapshotsWritten, 1);
        old.gauge_max(MetricId::MaxBatchRows, 7);
        old.record_hist(HistId::CheckpointUs, 900);
        keep.absorb(&old);
        assert_eq!(keep.get(MetricId::SnapshotsWritten), 3);
        assert_eq!(keep.get(MetricId::MaxBatchRows), 7);
        let h = keep.snapshot().hist(HistId::CheckpointUs).clone();
        assert_eq!((h.count, h.min, h.max), (2, 100, 900));
    }

    #[test]
    fn snapshot_encoding_is_canonical_and_strict() {
        let r = Registry::new();
        r.add(MetricId::PredictsServed, 41);
        r.gauge_max(MetricId::MaxPendingRows, 6);
        for v in [0u64, 3, 17, 17, 250_000] {
            r.record_hist(HistId::WindowOccupancyRows, v);
        }
        let mut snap = r.snapshot();
        snap.spans.push(SpanEvent { t_us: 12, kind: SpanKind::Accept, a: 1, b: 0 });
        snap.spans.push(SpanEvent { t_us: 90, kind: SpanKind::Shed, a: 2, b: 5 });

        let mut bytes = Vec::new();
        snap.encode(&mut bytes);
        // determinism: re-encoding is bitwise identical
        let mut again = Vec::new();
        snap.encode(&mut again);
        assert_eq!(bytes, again);

        let mut cur = Cursor::new(&bytes, "test");
        let back = TelemetrySnapshot::decode(&mut cur, "test").unwrap();
        assert!(cur.is_empty(), "decode consumed everything");
        assert_eq!(back, snap);

        // every single-byte corruption is rejected or changes the value —
        // never silently accepted as the same snapshot
        for i in 0..bytes.len() {
            let mut bad = bytes.clone();
            bad[i] ^= 0x01;
            let mut cur = Cursor::new(&bad, "test");
            match TelemetrySnapshot::decode(&mut cur, "test") {
                Err(_) => {}
                Ok(other) => assert!(
                    other != snap || !cur.is_empty(),
                    "flip at byte {i} decoded to an identical snapshot"
                ),
            }
        }

        // truncation at every boundary is corruption
        for cut in 0..bytes.len() {
            let mut cur = Cursor::new(&bytes[..cut], "test");
            let r = TelemetrySnapshot::decode(&mut cur, "test");
            assert!(r.is_err() || !cur.is_empty(), "cut at {cut}");
        }
    }

    #[test]
    fn render_and_json_name_live_slots() {
        let r = Registry::new();
        r.add(MetricId::ShedPredict, 8);
        r.record_hist(HistId::RoundLatencyUs, 420);
        let mut snap = r.snapshot();
        snap.spans.push(SpanEvent { t_us: 3, kind: SpanKind::Quarantine, a: 1, b: 2 });
        let text = snap.render_text();
        assert!(text.contains("shed_predict"), "{text}");
        assert!(text.contains("round_latency_us"), "{text}");
        assert!(text.contains("quarantine"), "{text}");
        let mut json = String::new();
        snap.write_json(&mut json);
        assert!(json.contains("\"shed_predict\": 8"), "{json}");
        assert!(json.contains("\"round_latency_us\""), "{json}");
        assert!(json.contains("\"kind\": \"quarantine\""), "{json}");
    }
}
