//! Fan-out: one pooled stream feeding per-shard sinks.
//!
//! The fusion-center [`SinkNode`] pools every sensor into one stream; the
//! sharded serving layer wants K independent per-shard streams so each
//! shard batches its own slice. [`spawn_fanout`] bridges the two: a
//! forwarding thread drains the pooled sink and pushes each event down the
//! shard channel the routing closure picks. Backpressure composes: a slow
//! shard fills its bounded channel, the forwarder blocks, the pooled sink
//! fills, and the sensors block — the same discipline as the rest of the
//! pipeline.
//!
//! Seal the upstream sink (see [`SinkNode::seal`]) before spawning the
//! forwarder if the stream is finite: the forwarder exits when the pooled
//! stream disconnects (or when every shard receiver hangs up).

use super::sink::SinkNode;
use super::StreamEvent;
use std::sync::mpsc::SyncSender;
use std::thread::JoinHandle;
use std::time::Duration;

/// Spawn a forwarding thread that routes every pooled event onto one of
/// the shard channels. `route` returns a shard index (reduced modulo the
/// channel count). A shard whose receiver hangs up is marked dead and its
/// events are dropped from then on — the healthy shards keep receiving.
/// Returns the forwarder handle; joining it yields the number of events
/// forwarded (dead-shard drops excluded).
pub fn spawn_fanout(
    mut sink: SinkNode,
    txs: Vec<SyncSender<StreamEvent>>,
    mut route: impl FnMut(&StreamEvent) -> usize + Send + 'static,
) -> JoinHandle<usize> {
    assert!(!txs.is_empty(), "fanout needs at least one shard channel");
    std::thread::spawn(move || {
        let mut txs: Vec<Option<SyncSender<StreamEvent>>> =
            txs.into_iter().map(Some).collect();
        let mut alive = txs.len();
        let mut forwarded = 0usize;
        loop {
            match sink.recv_timeout(Duration::from_millis(50)) {
                Some(ev) => {
                    let s = route(&ev) % txs.len();
                    // a dead shard's events are dropped
                    if let Some(tx) = &txs[s] {
                        if tx.send(ev).is_ok() {
                            forwarded += 1;
                        } else {
                            // receiver hung up: retire this shard only
                            txs[s] = None;
                            alive -= 1;
                            if alive == 0 {
                                break;
                            }
                        }
                    }
                }
                None => {
                    if sink.is_disconnected() {
                        break; // sealed upstream fully drained
                    }
                }
            }
        }
        forwarded
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::streaming::source::{SensorNode, SourceConfig};

    #[test]
    fn splits_one_stream_across_shard_sinks() {
        let mut pooled = SinkNode::new(16);
        let mut handles = Vec::new();
        for sid in 0..2 {
            let shard = synth::ecg_like(20, 4, 10 + sid as u64);
            let cfg = SourceConfig { source_id: sid, ..Default::default() };
            handles.push(SensorNode::new(shard, cfg).spawn(pooled.sender()));
        }
        pooled.seal();
        let mut shard_sinks: Vec<SinkNode> = (0..3).map(|_| SinkNode::new(16)).collect();
        let txs: Vec<_> = shard_sinks.iter().map(|s| s.sender()).collect();
        for s in &mut shard_sinks {
            s.seal();
        }
        // round-robin routing via a stateful closure
        let mut next = 0usize;
        let fwd = spawn_fanout(pooled, txs, move |_| {
            let s = next;
            next += 1;
            s
        });
        let mut got = vec![0usize; 3];
        for (i, s) in shard_sinks.iter_mut().enumerate() {
            loop {
                let evs = s.drain(32, Duration::from_millis(500));
                if evs.is_empty() && s.is_disconnected() {
                    break;
                }
                got[i] += evs.len();
            }
        }
        assert_eq!(fwd.join().unwrap(), 40);
        assert_eq!(got.iter().sum::<usize>(), 40);
        // round robin keeps the split balanced
        assert!(got.iter().all(|&g| (13..=14).contains(&g)), "{got:?}");
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn dead_shard_does_not_starve_healthy_shards() {
        let mut pooled = SinkNode::new(16);
        let d = synth::ecg_like(40, 3, 13);
        let h = SensorNode::new(d, SourceConfig::default()).spawn(pooled.sender());
        pooled.seal();
        let mut healthy = SinkNode::new(64);
        let dead = SinkNode::new(1);
        let txs = vec![dead.sender(), healthy.sender()];
        healthy.seal();
        drop(dead); // shard 0's receiver is gone before anything flows
        let mut next = 0usize;
        let fwd = spawn_fanout(pooled, txs, move |_| {
            let s = next;
            next += 1;
            s
        });
        let mut got = 0usize;
        loop {
            let evs = healthy.drain(32, Duration::from_millis(500));
            if evs.is_empty() && healthy.is_disconnected() {
                break;
            }
            got += evs.len();
        }
        assert_eq!(fwd.join().unwrap(), 20, "healthy shard's share forwarded");
        assert_eq!(got, 20, "shard 1 must keep receiving after shard 0 dies");
        h.join().unwrap();
    }

    #[test]
    fn forwarder_stops_when_shard_receiver_hangs_up() {
        let mut pooled = SinkNode::new(4);
        let shard = synth::ecg_like(1000, 3, 12);
        let h = SensorNode::new(shard, SourceConfig::default()).spawn(pooled.sender());
        pooled.seal();
        let shard_sink = SinkNode::new(1);
        let tx = shard_sink.sender();
        let fwd = spawn_fanout(pooled, vec![tx], |_| 0);
        drop(shard_sink); // receiver gone: forwarder must exit promptly
        let forwarded = fwd.join().unwrap();
        assert!(forwarded < 1000);
        h.join().unwrap();
    }
}
