//! The streaming substrate: sensor sources, sink-node pooling (paper
//! Fig. 1), batching with backpressure, and residual-based outlier
//! detection that feeds the decremental path.
//!
//! Threading model: each [`source::SensorNode`] runs on its own thread and
//! pushes into a bounded channel (backpressure = blocking send); the
//! [`sink::SinkNode`] fans the channels into one pooled stream; the
//! [`batcher::Batcher`] groups pooled events into multiple-update batches
//! by size/time policy; [`fanout::spawn_fanout`] re-splits the pooled
//! stream into per-shard sinks for the [`crate::serve`] layer.  All of it
//! is std-only (`mpsc` + threads).

pub mod batcher;
pub mod fanout;
pub mod outlier;
pub mod sink;
pub mod source;

/// One labelled observation travelling through the pipeline.
///
/// Multi-output targets split into `y` (output 0) plus `y_tail` (outputs
/// `1..D`), so single-output traffic — the overwhelmingly common case —
/// pays no layout change: `y_tail` stays empty and never allocates.
#[derive(Clone, Debug)]
pub struct StreamEvent {
    /// Feature vector.
    pub x: Vec<f64>,
    /// Target / label (output column 0).
    pub y: f64,
    /// Remaining target columns `1..D`; empty for single-output streams.
    pub y_tail: Vec<f64>,
    /// Originating sensor id.
    pub source_id: usize,
    /// Per-source sequence number.
    pub seq: u64,
}

impl StreamEvent {
    /// A single-output (`D = 1`) event.
    pub fn single(x: Vec<f64>, y: f64, source_id: usize, seq: u64) -> Self {
        Self { x, y, y_tail: Vec::new(), source_id, seq }
    }

    /// A multi-output event: `y_row` carries all `D >= 1` target columns.
    pub fn multi(x: Vec<f64>, y_row: &[f64], source_id: usize, seq: u64) -> Self {
        assert!(!y_row.is_empty(), "multi-output event needs >= 1 target");
        Self {
            x,
            y: y_row[0],
            y_tail: y_row[1..].to_vec(),
            source_id,
            seq,
        }
    }

    /// Number of target columns this event carries.
    pub fn n_outputs(&self) -> usize {
        1 + self.y_tail.len()
    }

    /// True when every payload float (features AND all target columns) is
    /// finite. A NaN/±Inf row admitted into an engine poisons the Gram
    /// matrix and, through the maintained inverse, every prediction after
    /// it — so the serve boundary rejects on this before any engine sees
    /// the event.
    pub fn is_finite(&self) -> bool {
        self.x.iter().all(|v| v.is_finite())
            && self.y.is_finite()
            && self.y_tail.iter().all(|v| v.is_finite())
    }

    /// Full boundary validation: feature dimension, target-column count,
    /// and float finiteness. `Err(Error::InvalidUpdate)` on any violation —
    /// the event can never be applied, so callers drop (and count) it
    /// rather than requeue it.
    pub fn validate(&self, dim: usize, n_outputs: usize) -> crate::error::Result<()> {
        if self.x.len() != dim {
            return Err(crate::error::Error::InvalidUpdate(format!(
                "event (source {}, seq {}) has dim {}, expected {dim}",
                self.source_id,
                self.seq,
                self.x.len()
            )));
        }
        if self.n_outputs() != n_outputs {
            return Err(crate::error::Error::InvalidUpdate(format!(
                "event (source {}, seq {}) carries {} target columns, expected {n_outputs}",
                self.source_id,
                self.seq,
                self.n_outputs()
            )));
        }
        if !self.is_finite() {
            return Err(crate::error::Error::InvalidUpdate(format!(
                "event (source {}, seq {}) carries non-finite values",
                self.source_id, self.seq
            )));
        }
        Ok(())
    }

    /// Append this event's wire form to `out` — the payload format of the
    /// durability layer's WAL `Batch` records ([`crate::persist::wal`]).
    ///
    /// Layout (all little-endian): `[seq u64][source_id u64]
    /// [dim u32][x: dim f64][tail u32][y f64][y_tail: tail f64]`, with
    /// every `f64` as its IEEE-754 bit pattern so replay is bit-exact
    /// (the CRC lives one framing layer up, on the whole WAL record).
    pub fn encode_into(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.seq.to_le_bytes());
        out.extend_from_slice(&(self.source_id as u64).to_le_bytes());
        out.extend_from_slice(&(self.x.len() as u32).to_le_bytes());
        for &v in &self.x {
            out.extend_from_slice(&v.to_bits().to_le_bytes());
        }
        out.extend_from_slice(&(self.y_tail.len() as u32).to_le_bytes());
        out.extend_from_slice(&self.y.to_bits().to_le_bytes());
        for &v in &self.y_tail {
            out.extend_from_slice(&v.to_bits().to_le_bytes());
        }
    }

    /// Decode one event from `buf` starting at `*pos`, advancing `*pos`
    /// past it. Truncation or hostile lengths surface as permanent
    /// [`crate::error::Error::Persist`] corruption — the WAL reader treats
    /// a record that passed its CRC but fails here as a codec version bug,
    /// not a torn tail.
    pub fn decode_from(buf: &[u8], pos: &mut usize) -> crate::error::Result<StreamEvent> {
        const CTX: &str = "StreamEvent::decode_from";
        let corrupt =
            |d: String| crate::error::Error::persist_corruption(CTX, d);
        let take = |pos: &mut usize, n: usize| -> crate::error::Result<&[u8]> {
            if buf.len().saturating_sub(*pos) < n {
                return Err(crate::error::Error::persist_corruption(
                    CTX,
                    format!(
                        "truncated: wanted {n} bytes at offset {pos}, have {}",
                        buf.len().saturating_sub(*pos)
                    ),
                ));
            }
            let s = &buf[*pos..*pos + n];
            *pos += n;
            Ok(s)
        };
        let take_u64 = |pos: &mut usize| -> crate::error::Result<u64> {
            let b = take(pos, 8)?;
            Ok(u64::from_le_bytes(b.try_into().unwrap()))
        };
        let take_u32 = |pos: &mut usize| -> crate::error::Result<u32> {
            let b = take(pos, 4)?;
            Ok(u32::from_le_bytes(b.try_into().unwrap()))
        };
        let seq = take_u64(pos)?;
        let source = take_u64(pos)?;
        let source_id = usize::try_from(source)
            .map_err(|_| corrupt(format!("source_id {source} overflows usize")))?;
        let dim = take_u32(pos)? as usize;
        // bound allocations by what the buffer can actually hold
        if buf.len().saturating_sub(*pos) < dim.saturating_mul(8) {
            return Err(corrupt(format!("dim {dim} exceeds remaining bytes")));
        }
        let mut x = Vec::with_capacity(dim);
        for _ in 0..dim {
            x.push(f64::from_bits(take_u64(pos)?));
        }
        let tail = take_u32(pos)? as usize;
        if buf.len().saturating_sub(*pos) < tail.saturating_mul(8).saturating_add(8) {
            return Err(corrupt(format!("tail {tail} exceeds remaining bytes")));
        }
        let y = f64::from_bits(take_u64(pos)?);
        let mut y_tail = Vec::with_capacity(tail);
        for _ in 0..tail {
            y_tail.push(f64::from_bits(take_u64(pos)?));
        }
        Ok(StreamEvent { x, y, y_tail, source_id, seq })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn event_holds_payload() {
        let e = StreamEvent::single(vec![1.0, 2.0], -1.0, 3, 9);
        assert_eq!(e.x.len(), 2);
        assert_eq!(e.source_id, 3);
        assert_eq!(e.n_outputs(), 1);
        assert!(e.y_tail.is_empty());
    }

    #[test]
    fn multi_event_splits_head_and_tail() {
        let e = StreamEvent::multi(vec![0.5], &[1.0, 2.0, 3.0], 0, 1);
        assert_eq!(e.y, 1.0);
        assert_eq!(e.y_tail, vec![2.0, 3.0]);
        assert_eq!(e.n_outputs(), 3);
    }

    #[test]
    fn validate_rejects_nonfinite_and_bad_shapes() {
        let good = StreamEvent::multi(vec![1.0, 2.0], &[0.5, -0.5], 0, 0);
        assert!(good.is_finite());
        assert!(good.validate(2, 2).is_ok());
        assert!(good.validate(3, 2).is_err(), "wrong dim");
        assert!(good.validate(2, 1).is_err(), "wrong D");
        let nan_x = StreamEvent::single(vec![1.0, f64::NAN], 0.0, 0, 1);
        assert!(!nan_x.is_finite());
        assert!(matches!(
            nan_x.validate(2, 1),
            Err(crate::error::Error::InvalidUpdate(_))
        ));
        let inf_y = StreamEvent::single(vec![1.0, 2.0], f64::INFINITY, 0, 2);
        assert!(inf_y.validate(2, 1).is_err());
        let nan_tail = StreamEvent::multi(vec![1.0, 2.0], &[0.0, f64::NEG_INFINITY], 0, 3);
        assert!(nan_tail.validate(2, 2).is_err());
    }

    #[test]
    fn wire_codec_round_trips_bit_exact() {
        let events = [
            StreamEvent::single(vec![1.5, -2.25, 0.0], -0.0, 7, 42),
            StreamEvent::multi(vec![f64::MIN_POSITIVE], &[1.0, 2.0, -3.5, 1e-300], 0, 1),
            StreamEvent::single(Vec::new(), 9.75, usize::MAX, u64::MAX),
        ];
        let mut buf = Vec::new();
        for e in &events {
            e.encode_into(&mut buf);
        }
        let mut pos = 0;
        for e in &events {
            let d = StreamEvent::decode_from(&buf, &mut pos).unwrap();
            assert_eq!(d.seq, e.seq);
            assert_eq!(d.source_id, e.source_id);
            assert_eq!(
                d.x.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                e.x.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
            );
            assert_eq!(d.y.to_bits(), e.y.to_bits());
            assert_eq!(
                d.y_tail.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                e.y_tail.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
            );
        }
        assert_eq!(pos, buf.len(), "decoder consumed exactly what was written");
    }

    #[test]
    fn wire_codec_rejects_truncation_and_hostile_lengths() {
        let e = StreamEvent::multi(vec![1.0, 2.0, 3.0], &[0.5, -0.5], 3, 11);
        let mut buf = Vec::new();
        e.encode_into(&mut buf);
        for cut in 0..buf.len() {
            let mut pos = 0;
            let r = StreamEvent::decode_from(&buf[..cut], &mut pos);
            assert!(r.is_err(), "cut at {cut} decoded anyway");
            assert!(!r.unwrap_err().is_transient(), "codec failures are permanent");
        }
        // inflate the dim field (offset 16) far past the buffer: must be
        // rejected before any allocation sized by it
        let mut hostile = buf.clone();
        hostile[16..20].copy_from_slice(&u32::MAX.to_le_bytes());
        let mut pos = 0;
        assert!(StreamEvent::decode_from(&hostile, &mut pos).is_err());
    }
}
