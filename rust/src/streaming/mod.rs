//! The streaming substrate: sensor sources, sink-node pooling (paper
//! Fig. 1), batching with backpressure, and residual-based outlier
//! detection that feeds the decremental path.
//!
//! Threading model: each [`source::SensorNode`] runs on its own thread and
//! pushes into a bounded channel (backpressure = blocking send); the
//! [`sink::SinkNode`] fans the channels into one pooled stream; the
//! [`batcher::Batcher`] groups pooled events into multiple-update batches
//! by size/time policy; [`fanout::spawn_fanout`] re-splits the pooled
//! stream into per-shard sinks for the [`crate::serve`] layer.  All of it
//! is std-only (`mpsc` + threads).

pub mod batcher;
pub mod fanout;
pub mod outlier;
pub mod sink;
pub mod source;

/// One labelled observation travelling through the pipeline.
///
/// Multi-output targets split into `y` (output 0) plus `y_tail` (outputs
/// `1..D`), so single-output traffic — the overwhelmingly common case —
/// pays no layout change: `y_tail` stays empty and never allocates.
#[derive(Clone, Debug)]
pub struct StreamEvent {
    /// Feature vector.
    pub x: Vec<f64>,
    /// Target / label (output column 0).
    pub y: f64,
    /// Remaining target columns `1..D`; empty for single-output streams.
    pub y_tail: Vec<f64>,
    /// Originating sensor id.
    pub source_id: usize,
    /// Per-source sequence number.
    pub seq: u64,
}

impl StreamEvent {
    /// A single-output (`D = 1`) event.
    pub fn single(x: Vec<f64>, y: f64, source_id: usize, seq: u64) -> Self {
        Self { x, y, y_tail: Vec::new(), source_id, seq }
    }

    /// A multi-output event: `y_row` carries all `D >= 1` target columns.
    pub fn multi(x: Vec<f64>, y_row: &[f64], source_id: usize, seq: u64) -> Self {
        assert!(!y_row.is_empty(), "multi-output event needs >= 1 target");
        Self {
            x,
            y: y_row[0],
            y_tail: y_row[1..].to_vec(),
            source_id,
            seq,
        }
    }

    /// Number of target columns this event carries.
    pub fn n_outputs(&self) -> usize {
        1 + self.y_tail.len()
    }

    /// True when every payload float (features AND all target columns) is
    /// finite. A NaN/±Inf row admitted into an engine poisons the Gram
    /// matrix and, through the maintained inverse, every prediction after
    /// it — so the serve boundary rejects on this before any engine sees
    /// the event.
    pub fn is_finite(&self) -> bool {
        self.x.iter().all(|v| v.is_finite())
            && self.y.is_finite()
            && self.y_tail.iter().all(|v| v.is_finite())
    }

    /// Full boundary validation: feature dimension, target-column count,
    /// and float finiteness. `Err(Error::InvalidUpdate)` on any violation —
    /// the event can never be applied, so callers drop (and count) it
    /// rather than requeue it.
    pub fn validate(&self, dim: usize, n_outputs: usize) -> crate::error::Result<()> {
        if self.x.len() != dim {
            return Err(crate::error::Error::InvalidUpdate(format!(
                "event (source {}, seq {}) has dim {}, expected {dim}",
                self.source_id,
                self.seq,
                self.x.len()
            )));
        }
        if self.n_outputs() != n_outputs {
            return Err(crate::error::Error::InvalidUpdate(format!(
                "event (source {}, seq {}) carries {} target columns, expected {n_outputs}",
                self.source_id,
                self.seq,
                self.n_outputs()
            )));
        }
        if !self.is_finite() {
            return Err(crate::error::Error::InvalidUpdate(format!(
                "event (source {}, seq {}) carries non-finite values",
                self.source_id, self.seq
            )));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn event_holds_payload() {
        let e = StreamEvent::single(vec![1.0, 2.0], -1.0, 3, 9);
        assert_eq!(e.x.len(), 2);
        assert_eq!(e.source_id, 3);
        assert_eq!(e.n_outputs(), 1);
        assert!(e.y_tail.is_empty());
    }

    #[test]
    fn multi_event_splits_head_and_tail() {
        let e = StreamEvent::multi(vec![0.5], &[1.0, 2.0, 3.0], 0, 1);
        assert_eq!(e.y, 1.0);
        assert_eq!(e.y_tail, vec![2.0, 3.0]);
        assert_eq!(e.n_outputs(), 3);
    }

    #[test]
    fn validate_rejects_nonfinite_and_bad_shapes() {
        let good = StreamEvent::multi(vec![1.0, 2.0], &[0.5, -0.5], 0, 0);
        assert!(good.is_finite());
        assert!(good.validate(2, 2).is_ok());
        assert!(good.validate(3, 2).is_err(), "wrong dim");
        assert!(good.validate(2, 1).is_err(), "wrong D");
        let nan_x = StreamEvent::single(vec![1.0, f64::NAN], 0.0, 0, 1);
        assert!(!nan_x.is_finite());
        assert!(matches!(
            nan_x.validate(2, 1),
            Err(crate::error::Error::InvalidUpdate(_))
        ));
        let inf_y = StreamEvent::single(vec![1.0, 2.0], f64::INFINITY, 0, 2);
        assert!(inf_y.validate(2, 1).is_err());
        let nan_tail = StreamEvent::multi(vec![1.0, 2.0], &[0.0, f64::NEG_INFINITY], 0, 3);
        assert!(nan_tail.validate(2, 2).is_err());
    }
}
