//! The streaming substrate: sensor sources, sink-node pooling (paper
//! Fig. 1), batching with backpressure, and residual-based outlier
//! detection that feeds the decremental path.
//!
//! Threading model: each [`source::SensorNode`] runs on its own thread and
//! pushes into a bounded channel (backpressure = blocking send); the
//! [`sink::SinkNode`] fans the channels into one pooled stream; the
//! [`batcher::Batcher`] groups pooled events into multiple-update batches
//! by size/time policy; [`fanout::spawn_fanout`] re-splits the pooled
//! stream into per-shard sinks for the [`crate::serve`] layer.  All of it
//! is std-only (`mpsc` + threads).

pub mod batcher;
pub mod fanout;
pub mod outlier;
pub mod sink;
pub mod source;

/// One labelled observation travelling through the pipeline.
///
/// Multi-output targets split into `y` (output 0) plus `y_tail` (outputs
/// `1..D`), so single-output traffic — the overwhelmingly common case —
/// pays no layout change: `y_tail` stays empty and never allocates.
#[derive(Clone, Debug)]
pub struct StreamEvent {
    /// Feature vector.
    pub x: Vec<f64>,
    /// Target / label (output column 0).
    pub y: f64,
    /// Remaining target columns `1..D`; empty for single-output streams.
    pub y_tail: Vec<f64>,
    /// Originating sensor id.
    pub source_id: usize,
    /// Per-source sequence number.
    pub seq: u64,
}

impl StreamEvent {
    /// A single-output (`D = 1`) event.
    pub fn single(x: Vec<f64>, y: f64, source_id: usize, seq: u64) -> Self {
        Self { x, y, y_tail: Vec::new(), source_id, seq }
    }

    /// A multi-output event: `y_row` carries all `D >= 1` target columns.
    pub fn multi(x: Vec<f64>, y_row: &[f64], source_id: usize, seq: u64) -> Self {
        assert!(!y_row.is_empty(), "multi-output event needs >= 1 target");
        Self {
            x,
            y: y_row[0],
            y_tail: y_row[1..].to_vec(),
            source_id,
            seq,
        }
    }

    /// Number of target columns this event carries.
    pub fn n_outputs(&self) -> usize {
        1 + self.y_tail.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn event_holds_payload() {
        let e = StreamEvent::single(vec![1.0, 2.0], -1.0, 3, 9);
        assert_eq!(e.x.len(), 2);
        assert_eq!(e.source_id, 3);
        assert_eq!(e.n_outputs(), 1);
        assert!(e.y_tail.is_empty());
    }

    #[test]
    fn multi_event_splits_head_and_tail() {
        let e = StreamEvent::multi(vec![0.5], &[1.0, 2.0, 3.0], 0, 1);
        assert_eq!(e.y, 1.0);
        assert_eq!(e.y_tail, vec![2.0, 3.0]);
        assert_eq!(e.n_outputs(), 3);
    }
}
