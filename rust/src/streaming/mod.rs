//! The streaming substrate: sensor sources, sink-node pooling (paper
//! Fig. 1), batching with backpressure, and residual-based outlier
//! detection that feeds the decremental path.
//!
//! Threading model: each [`source::SensorNode`] runs on its own thread and
//! pushes into a bounded channel (backpressure = blocking send); the
//! [`sink::SinkNode`] fans the channels into one pooled stream; the
//! [`batcher::Batcher`] groups pooled events into multiple-update batches
//! by size/time policy; [`fanout::spawn_fanout`] re-splits the pooled
//! stream into per-shard sinks for the [`crate::serve`] layer.  All of it
//! is std-only (`mpsc` + threads).

pub mod batcher;
pub mod fanout;
pub mod outlier;
pub mod sink;
pub mod source;

/// One labelled observation travelling through the pipeline.
#[derive(Clone, Debug)]
pub struct StreamEvent {
    /// Feature vector.
    pub x: Vec<f64>,
    /// Target / label.
    pub y: f64,
    /// Originating sensor id.
    pub source_id: usize,
    /// Per-source sequence number.
    pub seq: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn event_holds_payload() {
        let e = StreamEvent { x: vec![1.0, 2.0], y: -1.0, source_id: 3, seq: 9 };
        assert_eq!(e.x.len(), 2);
        assert_eq!(e.source_id, 3);
    }
}
