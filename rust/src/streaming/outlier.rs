//! Residual-based outlier detection — the producer of decremental work.
//!
//! The paper motivates decremental learning as "removal of unnecessary
//! outliers".  This detector scores training samples by their
//! leave-in residual |y_i − f(x_i)| in robust z-score units (median/MAD),
//! and nominates the worst offenders for removal, which the coordinator
//! folds into the same batched update as the arriving samples.

use crate::error::Result;
use crate::krr::KrrModel;
use crate::linalg::Mat;

/// Detector configuration.
#[derive(Clone, Debug)]
pub struct OutlierConfig {
    /// Robust z-score threshold (MAD units) above which a sample is an
    /// outlier candidate.
    pub z_threshold: f64,
    /// Cap on removals nominated per call (keeps |R| inside the §III.B
    /// bound and the batch budget).
    pub max_removals: usize,
}

impl Default for OutlierConfig {
    fn default() -> Self {
        Self { z_threshold: 4.0, max_removals: 2 }
    }
}

/// A nominated removal.
#[derive(Clone, Debug, PartialEq)]
pub struct OutlierVerdict {
    /// Index into the current training set.
    pub index: usize,
    /// Robust z-score of the residual.
    pub score: f64,
}

/// Score all training samples and nominate outliers.
///
/// `x`/`y` must be the model's current training set, in the model's
/// current index order.
pub fn detect(
    model: &dyn KrrModel,
    x: &Mat,
    y: &[f64],
    cfg: &OutlierConfig,
) -> Result<Vec<OutlierVerdict>> {
    assert_eq!(x.rows(), y.len());
    if y.is_empty() {
        return Ok(Vec::new());
    }
    let pred = model.predict(x)?;
    detect_scored(&pred, y, cfg)
}

/// Fast path: score from precomputed predictions (the coordinator uses the
/// engine's stored-feature `predict_training`, avoiding re-mapping the
/// whole training set every round).
pub fn detect_scored(
    pred: &[f64],
    y: &[f64],
    cfg: &OutlierConfig,
) -> Result<Vec<OutlierVerdict>> {
    assert_eq!(pred.len(), y.len());
    let resid: Vec<f64> = pred.iter().zip(y).map(|(p, t)| (p - t).abs()).collect();
    rank_residuals(resid, cfg)
}

/// Multi-output fast path: per-row residual = L2 norm of the D-column
/// prediction error, which reduces to `|p - t|` at `D = 1` so the two
/// paths score identically on single-output engines.
pub fn detect_scored_multi(
    pred: &Mat,
    y: &Mat,
    cfg: &OutlierConfig,
) -> Result<Vec<OutlierVerdict>> {
    assert_eq!(pred.shape(), y.shape());
    let resid: Vec<f64> = (0..pred.rows())
        .map(|i| {
            let s: f64 = pred
                .row(i)
                .iter()
                .zip(y.row(i))
                .map(|(p, t)| (p - t) * (p - t))
                .sum();
            s.sqrt()
        })
        .collect();
    rank_residuals(resid, cfg)
}

/// Robust z-score ranking (median + MAD) shared by the scored paths.
fn rank_residuals(resid: Vec<f64>, cfg: &OutlierConfig) -> Result<Vec<OutlierVerdict>> {
    let med = crate::util::stats::median(&resid);
    let dev: Vec<f64> = resid.iter().map(|r| (r - med).abs()).collect();
    let mad = crate::util::stats::median(&dev).max(1e-12);
    let mut verdicts: Vec<OutlierVerdict> = resid
        .iter()
        .enumerate()
        .filter_map(|(i, &r)| {
            let score = (r - med) / (1.4826 * mad);
            (score > cfg.z_threshold).then_some(OutlierVerdict { index: i, score })
        })
        .collect();
    verdicts.sort_by(|a, b| b.score.partial_cmp(&a.score).unwrap());
    verdicts.truncate(cfg.max_removals);
    Ok(verdicts)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::Kernel;
    use crate::krr::intrinsic::IntrinsicKrr;
    use crate::linalg::matrix::dot;
    use crate::util::prng::Rng;

    fn data_with_outliers(
        n: usize,
        m: usize,
        n_out: usize,
        seed: u64,
    ) -> (Mat, Vec<f64>, Vec<usize>) {
        let mut rng = Rng::new(seed);
        let w: Vec<f64> = rng.gaussian_vec(m);
        let x = Mat::from_fn(n, m, |_, _| 0.5 * rng.gaussian());
        let mut y: Vec<f64> = (0..n)
            .map(|i| dot(x.row(i), &w) + 0.02 * rng.gaussian())
            .collect();
        let mut idx = Vec::new();
        for k in 0..n_out {
            let i = (k * 7 + 3) % n;
            y[i] += 30.0; // gross label corruption
            idx.push(i);
        }
        (x, y, idx)
    }

    #[test]
    fn detects_injected_outliers() {
        let (x, y, inj) = data_with_outliers(60, 4, 2, 1);
        let model = IntrinsicKrr::fit(&x, &y, &Kernel::poly(2, 1.0), 0.5).unwrap();
        let cfg = OutlierConfig { z_threshold: 4.0, max_removals: 4 };
        let got = detect(&model, &x, &y, &cfg).unwrap();
        let got_idx: Vec<usize> = got.iter().map(|v| v.index).collect();
        for i in inj {
            assert!(got_idx.contains(&i), "missed injected outlier {i}: {got_idx:?}");
        }
    }

    #[test]
    fn clean_data_yields_nothing() {
        let (x, y, _) = data_with_outliers(50, 4, 0, 2);
        let model = IntrinsicKrr::fit(&x, &y, &Kernel::poly(2, 1.0), 0.5).unwrap();
        let got = detect(&model, &x, &y, &OutlierConfig::default()).unwrap();
        assert!(got.len() <= 1, "clean data flagged {got:?}");
    }

    #[test]
    fn multi_path_matches_scalar_path_at_d1() {
        let (x, y, _) = data_with_outliers(40, 4, 3, 4);
        let model = IntrinsicKrr::fit(&x, &y, &Kernel::poly(2, 1.0), 0.5).unwrap();
        let pred = model.predict(&x).unwrap();
        let cfg = OutlierConfig { z_threshold: 3.0, max_removals: 5 };
        let scalar = detect_scored(&pred, &y, &cfg).unwrap();
        let pm = Mat::from_vec(pred.len(), 1, pred.clone()).unwrap();
        let ym = Mat::from_vec(y.len(), 1, y.clone()).unwrap();
        let multi = detect_scored_multi(&pm, &ym, &cfg).unwrap();
        assert_eq!(scalar, multi);
    }

    #[test]
    fn respects_max_removals() {
        let (x, y, _) = data_with_outliers(80, 4, 10, 3);
        let model = IntrinsicKrr::fit(&x, &y, &Kernel::poly(2, 1.0), 0.5).unwrap();
        let cfg = OutlierConfig { z_threshold: 2.0, max_removals: 3 };
        let got = detect(&model, &x, &y, &cfg).unwrap();
        assert!(got.len() <= 3);
        // sorted by severity
        for w in got.windows(2) {
            assert!(w[0].score >= w[1].score);
        }
    }
}
