//! Sink-node pooling (paper Fig. 1): many sensor channels fan into one
//! pooled stream at the fusion center.
//!
//! `std::sync::mpsc` already supports multiple producers, so the sink is a
//! thin owner of the single receiver plus pool statistics; it exists as a
//! type so the coordinator can reason about "the fusion center" explicitly
//! (and to host the per-source accounting the paper's setting implies).

use super::StreamEvent;
use crate::metrics::Counters;
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender, TryRecvError};
use std::time::Duration;

/// The fusion-center pooling point.
pub struct SinkNode {
    rx: Receiver<StreamEvent>,
    /// The template sender handles are cloned from; dropped by [`seal`].
    /// While it is held the channel can never disconnect, so an unsealed
    /// sink always waits out its full receive timeout after sources finish.
    ///
    /// [`seal`]: SinkNode::seal
    tx_template: Option<SyncSender<StreamEvent>>,
    /// Set once a receive observes the channel disconnected (sealed sink,
    /// all source handles dropped).
    disconnected: bool,
    /// Per-source receive counts and totals.
    pub counters: Counters,
}

impl SinkNode {
    /// Create with a bounded pool of `capacity` in-flight events
    /// (backpressure: senders block when the pool is full).
    pub fn new(capacity: usize) -> Self {
        let (tx, rx) = sync_channel(capacity.max(1));
        Self {
            rx,
            tx_template: Some(tx),
            disconnected: false,
            counters: Counters::default(),
        }
    }

    /// A sender handle for one sensor node (clone per source).
    ///
    /// # Panics
    /// After [`SinkNode::seal`] — handing out senders to a sealed sink
    /// would silently reconnect a stream the owner declared finished.
    pub fn sender(&self) -> SyncSender<StreamEvent> {
        self.tx_template
            .as_ref()
            .expect("SinkNode::sender called after seal()")
            .clone()
    }

    /// Drop the sink's own template sender so the channel disconnects — and
    /// receives return promptly — once all source handles are dropped.
    /// Call after all `sender()` handles are handed out.
    pub fn seal(&mut self) {
        self.tx_template = None;
    }

    /// Whether [`SinkNode::seal`] has been called.
    pub fn is_sealed(&self) -> bool {
        self.tx_template.is_none()
    }

    /// Whether the channel has disconnected (sealed + every source handle
    /// dropped). Once true, no event can ever arrive again.
    pub fn is_disconnected(&self) -> bool {
        self.disconnected
    }

    /// Blocking receive with timeout; counts the event.  Returns `None`
    /// immediately (not after the timeout) once the stream disconnects.
    pub fn recv_timeout(&mut self, timeout: Duration) -> Option<StreamEvent> {
        if self.disconnected {
            return None;
        }
        match self.rx.recv_timeout(timeout) {
            Ok(ev) => {
                self.counters.inc(&format!("source.{}", ev.source_id));
                self.counters.inc("pooled");
                Some(ev)
            }
            Err(RecvTimeoutError::Timeout) => None,
            Err(RecvTimeoutError::Disconnected) => {
                self.disconnected = true;
                None
            }
        }
    }

    /// Drain up to `max` events without blocking longer than `timeout` for
    /// the first one (subsequent reads are non-blocking).  Returns promptly
    /// once the stream disconnects.
    pub fn drain(&mut self, max: usize, timeout: Duration) -> Vec<StreamEvent> {
        let mut out = Vec::new();
        if max == 0 {
            return out;
        }
        if let Some(first) = self.recv_timeout(timeout) {
            out.push(first);
            while out.len() < max {
                match self.rx.try_recv() {
                    Ok(ev) => {
                        self.counters.inc(&format!("source.{}", ev.source_id));
                        self.counters.inc("pooled");
                        out.push(ev);
                    }
                    Err(TryRecvError::Disconnected) => {
                        self.disconnected = true;
                        break;
                    }
                    Err(TryRecvError::Empty) => break,
                }
            }
        }
        out
    }

    /// Total pooled events.
    pub fn pooled(&self) -> u64 {
        self.counters.get("pooled")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::streaming::source::{SensorNode, SourceConfig};

    #[test]
    fn pools_multiple_sources() {
        let mut sink = SinkNode::new(16);
        let mut handles = Vec::new();
        for sid in 0..3 {
            let shard = synth::ecg_like(40, 4, 10 + sid as u64);
            let cfg = SourceConfig { source_id: sid, ..Default::default() };
            handles.push(SensorNode::new(shard, cfg).spawn(sink.sender()));
        }
        let mut got = 0;
        while got < 120 {
            let evs = sink.drain(32, Duration::from_millis(200));
            if evs.is_empty() {
                break;
            }
            got += evs.len();
        }
        assert_eq!(got, 120);
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(sink.pooled(), 120);
        assert!(sink.counters.get("source.0") == 40);
        assert!(sink.counters.get("source.2") == 40);
    }

    #[test]
    fn timeout_returns_none() {
        let mut sink = SinkNode::new(4);
        assert!(sink.recv_timeout(Duration::from_millis(10)).is_none());
        assert!(sink.drain(5, Duration::from_millis(10)).is_empty());
    }

    #[test]
    fn sealed_sink_disconnects_promptly_after_sources_finish() {
        let mut sink = SinkNode::new(16);
        let shard = synth::ecg_like(10, 3, 20);
        let h = SensorNode::new(shard, SourceConfig::default()).spawn(sink.sender());
        sink.seal();
        assert!(sink.is_sealed());
        // consume the stream; the generous timeout must NOT be burned once
        // the source thread exits and drops its handle
        let t0 = std::time::Instant::now();
        let mut got = 0;
        loop {
            let evs = sink.drain(32, Duration::from_secs(5));
            if evs.is_empty() {
                break;
            }
            got += evs.len();
        }
        h.join().unwrap();
        assert_eq!(got, 10);
        assert!(sink.is_disconnected());
        assert!(
            t0.elapsed() < Duration::from_secs(2),
            "drain burned the timeout after disconnect: {:?}",
            t0.elapsed()
        );
        // every subsequent receive is an immediate None
        let t1 = std::time::Instant::now();
        assert!(sink.recv_timeout(Duration::from_secs(5)).is_none());
        assert!(t1.elapsed() < Duration::from_millis(100));
    }

    #[test]
    #[should_panic(expected = "after seal")]
    fn sender_after_seal_panics() {
        let mut sink = SinkNode::new(4);
        sink.seal();
        let _ = sink.sender();
    }

    #[test]
    fn unsealed_sink_never_disconnects() {
        let mut sink = SinkNode::new(4);
        assert!(sink.recv_timeout(Duration::from_millis(10)).is_none());
        assert!(!sink.is_disconnected());
    }
}
