//! Sink-node pooling (paper Fig. 1): many sensor channels fan into one
//! pooled stream at the fusion center.
//!
//! `std::sync::mpsc` already supports multiple producers, so the sink is a
//! thin owner of the single receiver plus pool statistics; it exists as a
//! type so the coordinator can reason about "the fusion center" explicitly
//! (and to host the per-source accounting the paper's setting implies).

use super::StreamEvent;
use crate::metrics::Counters;
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender};
use std::time::Duration;

/// The fusion-center pooling point.
pub struct SinkNode {
    rx: Receiver<StreamEvent>,
    tx_template: SyncSender<StreamEvent>,
    /// Per-source receive counts and totals.
    pub counters: Counters,
}

impl SinkNode {
    /// Create with a bounded pool of `capacity` in-flight events
    /// (backpressure: senders block when the pool is full).
    pub fn new(capacity: usize) -> Self {
        let (tx, rx) = sync_channel(capacity.max(1));
        Self { rx, tx_template: tx, counters: Counters::default() }
    }

    /// A sender handle for one sensor node (clone per source).
    pub fn sender(&self) -> SyncSender<StreamEvent> {
        self.tx_template.clone()
    }

    /// Drop the sink's own sender so `recv` terminates once all sources
    /// finish.  Call after all `sender()` handles are handed out.
    pub fn seal(&mut self) {
        // Replace the template with a dummy disconnected sender by swapping
        // in a fresh channel's tx that we immediately drop the rx of — not
        // possible with mpsc; instead we rely on `recv_deadline` users or
        // explicit counts. Simplest correct approach: nothing to do if all
        // users use `recv_timeout`/`drain`. Kept for API clarity.
    }

    /// Blocking receive with timeout; counts the event.
    pub fn recv_timeout(&mut self, timeout: Duration) -> Option<StreamEvent> {
        match self.rx.recv_timeout(timeout) {
            Ok(ev) => {
                self.counters.inc(&format!("source.{}", ev.source_id));
                self.counters.inc("pooled");
                Some(ev)
            }
            Err(RecvTimeoutError::Timeout) | Err(RecvTimeoutError::Disconnected) => None,
        }
    }

    /// Drain up to `max` events without blocking longer than `timeout` for
    /// the first one (subsequent reads are non-blocking).
    pub fn drain(&mut self, max: usize, timeout: Duration) -> Vec<StreamEvent> {
        let mut out = Vec::new();
        if max == 0 {
            return out;
        }
        if let Some(first) = self.recv_timeout(timeout) {
            out.push(first);
            while out.len() < max {
                match self.rx.try_recv() {
                    Ok(ev) => {
                        self.counters.inc(&format!("source.{}", ev.source_id));
                        self.counters.inc("pooled");
                        out.push(ev);
                    }
                    Err(_) => break,
                }
            }
        }
        out
    }

    /// Total pooled events.
    pub fn pooled(&self) -> u64 {
        self.counters.get("pooled")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::streaming::source::{SensorNode, SourceConfig};

    #[test]
    fn pools_multiple_sources() {
        let mut sink = SinkNode::new(16);
        let mut handles = Vec::new();
        for sid in 0..3 {
            let shard = synth::ecg_like(40, 4, 10 + sid as u64);
            let cfg = SourceConfig { source_id: sid, ..Default::default() };
            handles.push(SensorNode::new(shard, cfg).spawn(sink.sender()));
        }
        let mut got = 0;
        while got < 120 {
            let evs = sink.drain(32, Duration::from_millis(200));
            if evs.is_empty() {
                break;
            }
            got += evs.len();
        }
        assert_eq!(got, 120);
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(sink.pooled(), 120);
        assert!(sink.counters.get("source.0") == 40);
        assert!(sink.counters.get("source.2") == 40);
    }

    #[test]
    fn timeout_returns_none() {
        let mut sink = SinkNode::new(4);
        assert!(sink.recv_timeout(Duration::from_millis(10)).is_none());
        assert!(sink.drain(5, Duration::from_millis(10)).is_empty());
    }
}
