//! Batching policy: group pooled events into multiple-update batches.
//!
//! The paper's core efficiency lever is issuing ONE rank-|H| update instead
//! of |H| rank-1 updates; the batcher decides |H| by a size/time policy,
//! bounded by the advisor's §II.B rule (|H| < J).

use super::StreamEvent;
use crate::streaming::sink::SinkNode;
use std::time::{Duration, Instant};

/// Size/time batching policy.
#[derive(Clone, Debug)]
pub struct BatchPolicy {
    /// Flush when this many events are pending (must be >= 1).
    pub max_batch: usize,
    /// Flush when the oldest pending event has waited this long.
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        Self { max_batch: 4, max_wait: Duration::from_millis(50) }
    }
}

/// Pull-side batcher over a [`SinkNode`].
pub struct Batcher {
    policy: BatchPolicy,
    pending: Vec<StreamEvent>,
    oldest: Option<Instant>,
}

impl Batcher {
    /// New with a policy.
    pub fn new(policy: BatchPolicy) -> Self {
        assert!(policy.max_batch >= 1, "max_batch must be >= 1");
        Self { policy, pending: Vec::new(), oldest: None }
    }

    /// Pull the next batch from the sink.  Returns an empty vec when the
    /// stream has gone quiet for `max_wait` with nothing pending, or
    /// immediately — flushing any partial batch — once a sealed sink's
    /// sources have all disconnected (nothing can arrive anymore, so
    /// waiting out the deadline would be pure latency).
    pub fn next_batch(&mut self, sink: &mut SinkNode) -> Vec<StreamEvent> {
        loop {
            let need = self.policy.max_batch - self.pending.len();
            let wait = match self.oldest {
                None => self.policy.max_wait,
                Some(t0) => self
                    .policy
                    .max_wait
                    .checked_sub(t0.elapsed())
                    .unwrap_or(Duration::ZERO),
            };
            let got = sink.drain(need, wait);
            if !got.is_empty() && self.oldest.is_none() {
                self.oldest = Some(Instant::now());
            }
            self.pending.extend(got);
            if sink.is_disconnected() {
                self.oldest = None;
                return std::mem::take(&mut self.pending);
            }
            let deadline_hit = self
                .oldest
                .map(|t0| t0.elapsed() >= self.policy.max_wait)
                .unwrap_or(false);
            if self.pending.len() >= self.policy.max_batch
                || (deadline_hit && !self.pending.is_empty())
            {
                self.oldest = None;
                return std::mem::take(&mut self.pending);
            }
            if self.pending.is_empty() && deadline_hit {
                return Vec::new();
            }
            if self.pending.is_empty() && self.oldest.is_none() {
                // nothing arrived within max_wait
                return Vec::new();
            }
        }
    }

    /// Number of events currently held.
    pub fn pending(&self) -> usize {
        self.pending.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::streaming::source::{SensorNode, SourceConfig};

    #[test]
    fn batches_by_size() {
        let mut sink = SinkNode::new(64);
        let shard = synth::ecg_like(10, 3, 1);
        let h = SensorNode::new(shard, SourceConfig::default()).spawn(sink.sender());
        let mut b =
            Batcher::new(BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(100) });
        let mut total = 0;
        let mut batches = 0;
        loop {
            let batch = b.next_batch(&mut sink);
            if batch.is_empty() {
                break;
            }
            assert!(batch.len() <= 4);
            total += batch.len();
            batches += 1;
        }
        assert_eq!(total, 10);
        assert!(batches >= 3);
        h.join().unwrap();
    }

    #[test]
    fn empty_stream_times_out() {
        let mut sink = SinkNode::new(4);
        let mut b = Batcher::new(BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(10) });
        let batch = b.next_batch(&mut sink);
        assert!(batch.is_empty());
    }

    #[test]
    fn partial_batch_flushes_on_deadline() {
        let mut sink = SinkNode::new(4);
        let shard = synth::ecg_like(3, 3, 2);
        let h = SensorNode::new(shard, SourceConfig::default()).spawn(sink.sender());
        let mut b =
            Batcher::new(BatchPolicy { max_batch: 10, max_wait: Duration::from_millis(30) });
        let batch = b.next_batch(&mut sink);
        assert_eq!(batch.len(), 3); // flushed by deadline, not size
        h.join().unwrap();
    }

    #[test]
    fn disconnect_flushes_partial_batch_without_waiting() {
        // a sealed sink whose sources finish must not make the batcher burn
        // max_wait: the partial batch flushes as soon as disconnect is seen
        let mut sink = SinkNode::new(8);
        let shard = synth::ecg_like(3, 3, 3);
        let h = SensorNode::new(shard, SourceConfig::default()).spawn(sink.sender());
        sink.seal();
        let mut b = Batcher::new(BatchPolicy {
            max_batch: 100,
            max_wait: Duration::from_secs(5),
        });
        let t0 = std::time::Instant::now();
        let batch = b.next_batch(&mut sink);
        assert_eq!(batch.len(), 3, "partial batch flushed on disconnect");
        assert!(
            t0.elapsed() < Duration::from_secs(2),
            "next_batch waited out max_wait: {:?}",
            t0.elapsed()
        );
        // stream is over: subsequent calls return empty immediately
        let t1 = std::time::Instant::now();
        assert!(b.next_batch(&mut sink).is_empty());
        assert!(t1.elapsed() < Duration::from_millis(100));
        h.join().unwrap();
    }
}
