//! Sensor-node sources: replay a dataset shard as a stream, with optional
//! label-noise outlier injection (the data the decremental path later
//! removes).

use super::StreamEvent;
use crate::data::Dataset;
use crate::util::prng::Rng;
use std::sync::mpsc::SyncSender;
use std::thread::JoinHandle;

/// Configuration for one sensor node.
#[derive(Clone, Debug)]
pub struct SourceConfig {
    /// Sensor id carried on every event.
    pub source_id: usize,
    /// Probability an emitted sample is an injected outlier (label flip +
    /// feature corruption).
    pub outlier_rate: f64,
    /// Optional artificial inter-arrival delay (keeps demos readable).
    pub delay: Option<std::time::Duration>,
    /// RNG seed.
    pub seed: u64,
}

impl Default for SourceConfig {
    fn default() -> Self {
        Self { source_id: 0, outlier_rate: 0.0, delay: None, seed: 1 }
    }
}

/// A sensor node replaying a dataset shard.
pub struct SensorNode {
    shard: Dataset,
    cfg: SourceConfig,
}

impl SensorNode {
    /// Create over a shard.
    pub fn new(shard: Dataset, cfg: SourceConfig) -> Self {
        Self { shard, cfg }
    }

    /// Generate the event sequence synchronously (for tests/drivers).
    pub fn events(&self) -> Vec<StreamEvent> {
        let mut rng = Rng::new(self.cfg.seed ^ (self.cfg.source_id as u64) << 17);
        (0..self.shard.len())
            .map(|i| self.make_event(i as u64, i, &mut rng))
            .collect()
    }

    fn make_event(&self, seq: u64, idx: usize, rng: &mut Rng) -> StreamEvent {
        let mut x = self.shard.x.row(idx).to_vec();
        let mut y = self.shard.y[idx];
        if rng.coin(self.cfg.outlier_rate) {
            // an outlier: flipped label + corrupted morphology
            y = -y;
            for v in x.iter_mut() {
                *v += 3.0 * rng.gaussian();
            }
        }
        StreamEvent::single(x, y, self.cfg.source_id, seq)
    }

    /// Spawn a thread pushing all events into `tx` (bounded — blocking send
    /// is the backpressure mechanism).  The thread ends when the shard is
    /// exhausted or the receiver hangs up.
    pub fn spawn(self, tx: SyncSender<StreamEvent>) -> JoinHandle<usize> {
        std::thread::spawn(move || {
            let mut rng =
                Rng::new(self.cfg.seed ^ (self.cfg.source_id as u64) << 17);
            let mut sent = 0usize;
            for i in 0..self.shard.len() {
                let ev = self.make_event(i as u64, i, &mut rng);
                if let Some(d) = self.cfg.delay {
                    std::thread::sleep(d);
                }
                if tx.send(ev).is_err() {
                    break; // sink gone: stop cleanly
                }
                sent += 1;
            }
            sent
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use std::sync::mpsc;

    #[test]
    fn replay_preserves_data_without_outliers() {
        let d = synth::ecg_like(20, 5, 1);
        let node = SensorNode::new(d.clone(), SourceConfig::default());
        let evs = node.events();
        assert_eq!(evs.len(), 20);
        assert_eq!(evs[3].x, d.x.row(3));
        assert_eq!(evs[3].y, d.y[3]);
        assert_eq!(evs[7].seq, 7);
    }

    #[test]
    fn outlier_injection_flips_labels() {
        let d = synth::ecg_like(200, 5, 2);
        let cfg = SourceConfig { outlier_rate: 1.0, ..Default::default() };
        let node = SensorNode::new(d.clone(), cfg);
        let evs = node.events();
        assert!(evs.iter().zip(&d.y).all(|(e, &y)| e.y == -y));
    }

    #[test]
    fn spawn_streams_through_channel() {
        let d = synth::ecg_like(50, 4, 3);
        let (tx, rx) = mpsc::sync_channel(4); // small buffer => backpressure
        let handle = SensorNode::new(d, SourceConfig::default()).spawn(tx);
        let got: Vec<StreamEvent> = rx.iter().collect();
        assert_eq!(got.len(), 50);
        assert_eq!(handle.join().unwrap(), 50);
    }

    #[test]
    fn receiver_hangup_stops_source() {
        let d = synth::ecg_like(10_000, 4, 4);
        let (tx, rx) = mpsc::sync_channel(1);
        let handle = SensorNode::new(d, SourceConfig::default()).spawn(tx);
        let _first = rx.recv().unwrap();
        drop(rx);
        let sent = handle.join().unwrap();
        assert!(sent < 10_000);
    }
}
