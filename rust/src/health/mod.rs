//! Numerical health monitoring and deterministic fault injection for the
//! self-healing serving tier.
//!
//! The paper's premise is one maintained inverse surviving thousands of
//! incremental/decremental rounds — but floating-point drift, a NaN sensor
//! row, or a near-singular batch can corrupt that inverse *silently*: the
//! engine keeps answering, every answer is wrong. This module gives the
//! serve layer the two missing pieces:
//!
//! * [`probe`] — cheap per-round residual checks on the maintained inverse
//!   (`‖row_i(A·A⁻¹ − I)‖∞` for a rotating sample of indices) plus a drift
//!   counter, so corruption is *detected* within a bounded number of rounds
//!   instead of never. When the counter trips, the supervisor self-heals
//!   via [`crate::coordinator::engine::Engine::refit`] on the writer copy
//!   while readers keep serving the last published epoch.
//! * [`fault`] — a seeded, deterministic [`fault::FaultPlan`] describing
//!   *which* shard suffers *what* fault at *which* round (NaN/Inf rows,
//!   poison batches, forced numerical failures, wedged shards, corrupted
//!   inverses). The plan logic is always compiled so it stays unit-tested;
//!   the injection call sites in `serve/` only exist under the `chaos`
//!   cargo feature and compile to nothing otherwise.

pub mod fault;
pub mod probe;

pub use fault::{FaultKind, FaultPlan, KillPoint, ScheduledFault};
pub use probe::{HealthProbe, HealthVerdict, ProbeConfig, ProbeReport};
