//! Residual health probes on the maintained inverse.
//!
//! A probe samples `k` indices of the residual operator `A·A⁻¹ − I`
//! (exactly zero in exact arithmetic) and reports the worst ∞-norm seen.
//! Indices rotate round-robin across calls, so over `ceil(dim / k)`
//! consecutive checks every row of the inverse gets inspected — a cheap
//! amortized full audit instead of an O(N³) verification per round. The
//! per-probe cost is one kernel/scatter row plus one symmetric mat-vec
//! (see `EmpiricalKrr::probe_residual_into` / `IntrinsicKrr::probe_residual_into`).
//!
//! Single breaches are tolerated (`Degraded`): one bad probe can be an
//! ill-conditioned row rather than real corruption. Only
//! [`ProbeConfig::trip_after`] *consecutive* breaching checks escalate to
//! `Critical`, which is the supervisor's signal to self-heal.

use crate::coordinator::engine::Engine;
use crate::error::Result;

/// Tuning knobs for a [`HealthProbe`].
#[derive(Clone, Debug)]
pub struct ProbeConfig {
    /// Residual indices sampled per check (clamped to the probe dim).
    pub samples: usize,
    /// ∞-norm residual above which a check counts as a breach.
    pub threshold: f64,
    /// Consecutive breaching checks before the verdict turns `Critical`.
    pub trip_after: usize,
}

impl Default for ProbeConfig {
    fn default() -> Self {
        // 1e-6 is ~1e8 ULPs of headroom over the ~1e-14 residuals a
        // healthy double-precision inverse shows at our problem sizes,
        // while still catching a single corrupted entry immediately.
        Self { samples: 4, threshold: 1e-6, trip_after: 2 }
    }
}

/// Outcome classification of one health check.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum HealthVerdict {
    /// All sampled residuals under threshold.
    Healthy,
    /// Breach seen, but not enough consecutive ones to trip yet.
    Degraded,
    /// `trip_after` consecutive breaching checks — self-heal now.
    Critical,
}

/// What one [`HealthProbe::check`] observed.
#[derive(Clone, Copy, Debug)]
pub struct ProbeReport {
    /// Worst ∞-norm residual across the sampled indices.
    pub max_residual: f64,
    /// Index that produced `max_residual`.
    pub worst_index: usize,
    /// Current consecutive-breach count (the drift counter).
    pub consecutive_breaches: usize,
    /// Classification under the probe's config.
    pub verdict: HealthVerdict,
}

/// Stateful rotating probe over one engine's maintained inverse.
///
/// Owns its scratch buffers, so a warm probe allocates nothing per check
/// (asserted in `rust/tests/alloc_count.rs` on the 1-thread path).
#[derive(Clone, Debug, Default)]
pub struct HealthProbe {
    cfg: ProbeConfig,
    /// Next residual index to sample (wraps at the engine's probe dim).
    cursor: usize,
    /// Consecutive checks that breached the threshold.
    consecutive_breaches: usize,
    /// Total checks run (diagnostics).
    checks: u64,
    /// Total breaching checks (diagnostics).
    breaches: u64,
    /// Warm probe scratch: rebuilt operator row, then residual row.
    g: Vec<f64>,
    r: Vec<f64>,
}

impl HealthProbe {
    /// New probe with the given config.
    pub fn new(cfg: ProbeConfig) -> Self {
        Self { cfg, ..Self::default() }
    }

    /// The probe's config.
    pub fn config(&self) -> &ProbeConfig {
        &self.cfg
    }

    /// Consecutive breaching checks so far (resets on a clean check).
    pub fn consecutive_breaches(&self) -> usize {
        self.consecutive_breaches
    }

    /// Lifetime (checks, breaches) counts.
    pub fn totals(&self) -> (u64, u64) {
        (self.checks, self.breaches)
    }

    /// Reset the drift counter and cursor — called after a self-heal so
    /// the healed engine starts from a clean slate.
    pub fn reset(&mut self) {
        self.cursor = 0;
        self.consecutive_breaches = 0;
    }

    /// Run one health check against `engine`: sample the next
    /// `min(samples, probe_dim)` residual indices (rotating cursor), update
    /// the drift counter, classify. Allocation-free once warm.
    pub fn check(&mut self, engine: &Engine) -> Result<ProbeReport> {
        let dim = engine.probe_dim();
        if dim == 0 {
            return Ok(ProbeReport {
                max_residual: 0.0,
                worst_index: 0,
                consecutive_breaches: self.consecutive_breaches,
                verdict: HealthVerdict::Healthy,
            });
        }
        let k = self.cfg.samples.min(dim).max(1);
        let mut max_residual = 0.0f64;
        let mut worst_index = self.cursor % dim;
        for _ in 0..k {
            let i = self.cursor % dim;
            self.cursor = (self.cursor + 1) % dim;
            let res = engine.probe_residual_into(i, &mut self.g, &mut self.r)?;
            if res > max_residual || !res.is_finite() {
                max_residual = if res.is_finite() { res } else { f64::INFINITY };
                worst_index = i;
            }
        }
        self.checks += 1;
        let breach = !(max_residual <= self.cfg.threshold);
        if breach {
            self.breaches += 1;
            self.consecutive_breaches += 1;
        } else {
            self.consecutive_breaches = 0;
        }
        let verdict = if !breach {
            HealthVerdict::Healthy
        } else if self.consecutive_breaches >= self.cfg.trip_after {
            HealthVerdict::Critical
        } else {
            HealthVerdict::Degraded
        };
        Ok(ProbeReport {
            max_residual,
            worst_index,
            consecutive_breaches: self.consecutive_breaches,
            verdict,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Space;
    use crate::data::synth;
    use crate::kernels::Kernel;

    fn engine(space: Space) -> Engine {
        let d = synth::ecg_like(30, 5, 31);
        Engine::fit(&d.x, &d.y, &Kernel::poly(2, 1.0), 0.5, space, false).unwrap()
    }

    #[test]
    fn healthy_engine_probes_healthy() {
        for space in [Space::Intrinsic, Space::Empirical] {
            let e = engine(space);
            let mut p = HealthProbe::new(ProbeConfig::default());
            // enough checks to rotate through every index at least once
            for _ in 0..(e.probe_dim() / 4 + 2) {
                let rep = p.check(&e).unwrap();
                assert_eq!(rep.verdict, HealthVerdict::Healthy, "{space:?}: {rep:?}");
                assert!(rep.max_residual < 1e-8);
            }
            assert_eq!(p.consecutive_breaches(), 0);
            let (checks, breaches) = p.totals();
            assert!(checks > 0);
            assert_eq!(breaches, 0);
        }
    }

    #[test]
    fn drift_counter_escalates_then_resets() {
        let e = engine(Space::Intrinsic);
        // threshold 0 below any float residual -> every check breaches
        let mut p = HealthProbe::new(ProbeConfig {
            samples: 2,
            threshold: -1.0,
            trip_after: 3,
        });
        assert_eq!(p.check(&e).unwrap().verdict, HealthVerdict::Degraded);
        assert_eq!(p.check(&e).unwrap().verdict, HealthVerdict::Degraded);
        let rep = p.check(&e).unwrap();
        assert_eq!(rep.verdict, HealthVerdict::Critical);
        assert_eq!(rep.consecutive_breaches, 3);
        p.reset();
        assert_eq!(p.consecutive_breaches(), 0);
        // with a sane threshold the same engine is healthy again
        let mut sane = HealthProbe::new(ProbeConfig::default());
        assert_eq!(sane.check(&e).unwrap().verdict, HealthVerdict::Healthy);
    }

    #[test]
    fn nan_residual_counts_as_breach() {
        // a probe must never classify NaN as under-threshold
        let breach = !(f64::NAN <= 1e-6);
        assert!(breach);
    }
}
