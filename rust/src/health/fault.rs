//! Deterministic fault injection plans for chaos testing.
//!
//! A [`FaultPlan`] is a seeded, fully precomputed schedule: *which* shard
//! suffers *what* [`FaultKind`] at *which* serving round. Two runs with the
//! same seed inject byte-identical faults, so a chaos failure reproduces
//! from nothing but its seed (the CI lane prints it).
//!
//! The plan type and its logic are ALWAYS compiled — they are plain data
//! and stay unit-tested in tier-1. Only the *injection call sites* in the
//! serve layer are gated behind the `chaos` cargo feature; without it the
//! hooks are empty `#[inline(always)]` functions and the whole mechanism
//! compiles to nothing.

use crate::util::prng::Rng;

/// One kind of injected failure.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FaultKind {
    /// Overwrite one pending event's features with NaN — must be rejected
    /// at the event boundary, never reach an engine.
    NanRow,
    /// Overwrite one pending event's features with +Inf — same boundary
    /// contract as [`FaultKind::NanRow`].
    InfRow,
    /// Overwrite one pending event's features with finite-but-huge values
    /// that overflow the Gram matrix — a *poison batch*: passes boundary
    /// validation, then fails numerically on every retry, and must end in
    /// batch quarantine rather than an infinite requeue.
    PoisonRow,
    /// Make the shard's update round return `Error::Numerical` once (the
    /// canonical transient failure — succeeds on retry).
    ForcedNumerical,
    /// Wedge the shard: its update rounds fail for the next `rounds`
    /// rounds, driving consecutive-failure shard quarantine while the
    /// router serves from the remaining K−1 shards.
    Wedge {
        /// How many consecutive rounds stay wedged.
        rounds: u32,
    },
    /// Multiply one entry of the maintained inverse by `factor` — silent
    /// corruption only a health probe can see, driving the self-heal path.
    CorruptInverse {
        /// Multiplicative corruption (e.g. `1.5` = 50% off).
        factor: f64,
    },
}

/// A deterministic crash point in the durability layer's write path
/// ([`crate::persist`]): every write / fsync / rename boundary in the
/// snapshot-checkpoint and WAL-append sequences has one. Like
/// [`FaultKind`], the enum is plain data and always compiles; the arming
/// registry and the injection sites ([`crate::persist::kill`]) only exist
/// under the `chaos` feature.
///
/// Semantics when armed: the FIRST time execution reaches the armed
/// point, the simulated process "dies" — that operation fails with a
/// transient [`crate::error::Error::Persist`], and every later persist
/// operation fails too (a dead process does not keep writing). `*Torn`
/// points additionally leave a partial frame on disk, which is what the
/// torn-tail truncation path must digest at recovery.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KillPoint {
    /// Mid-way through appending one WAL record (torn tail on disk).
    WalAppendTorn,
    /// After the full record bytes, before the WAL fsync.
    WalAppendFull,
    /// During the WAL fsync itself.
    WalFsync,
    /// Mid-way through the snapshot tmp-file body (torn tmp file).
    SnapTmpTorn,
    /// After the full tmp-file body, before its fsync.
    SnapTmpFull,
    /// During the tmp-file fsync.
    SnapTmpFsync,
    /// Between the tmp fsync and the atomic rename (tmp complete,
    /// snapshot not yet visible under its final name).
    SnapRename,
    /// After the rename, before the directory fsync that makes it durable.
    SnapDirFsync,
    /// After the snapshot landed, before the new WAL segment was created.
    SnapNewSegment,
    /// During old-generation garbage collection.
    SnapGc,
}

impl KillPoint {
    /// Every kill point, in write-path order — the recovery matrix test
    /// iterates this so a newly added boundary cannot dodge coverage.
    pub const ALL: [KillPoint; 10] = [
        KillPoint::WalAppendTorn,
        KillPoint::WalAppendFull,
        KillPoint::WalFsync,
        KillPoint::SnapTmpTorn,
        KillPoint::SnapTmpFull,
        KillPoint::SnapTmpFsync,
        KillPoint::SnapRename,
        KillPoint::SnapDirFsync,
        KillPoint::SnapNewSegment,
        KillPoint::SnapGc,
    ];
}

/// One scheduled fault.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ScheduledFault {
    /// Target shard index.
    pub shard: usize,
    /// Serving round (0-based supervisor round) at which it fires.
    pub round: u64,
    /// What happens.
    pub kind: FaultKind,
}

/// A deterministic, inspectable schedule of faults.
#[derive(Clone, Debug, Default)]
pub struct FaultPlan {
    /// Seed the plan was built from (0 for hand-built plans).
    pub seed: u64,
    faults: Vec<ScheduledFault>,
}

impl FaultPlan {
    /// Empty plan (hand-build with [`FaultPlan::push`]).
    pub fn new(seed: u64) -> Self {
        Self { seed, faults: Vec::new() }
    }

    /// Add one scheduled fault.
    pub fn push(&mut self, shard: usize, round: u64, kind: FaultKind) -> &mut Self {
        self.faults.push(ScheduledFault { shard, round, kind });
        self
    }

    /// Random plan: `n_faults` faults spread over `shards × rounds`,
    /// drawn deterministically from `seed`. Wedges and inverse corruption
    /// are scheduled early enough to also exercise the recovery half of
    /// their state machines within the run.
    pub fn random(seed: u64, shards: usize, rounds: u64, n_faults: usize) -> Self {
        assert!(shards > 0 && rounds > 0, "FaultPlan::random needs a grid");
        let mut rng = Rng::new(seed ^ 0xFA117_F1A9);
        let mut plan = Self::new(seed);
        for _ in 0..n_faults {
            let shard = rng.below(shards);
            let round = rng.below(rounds as usize) as u64;
            let kind = match rng.below(6) {
                0 => FaultKind::NanRow,
                1 => FaultKind::InfRow,
                2 => FaultKind::PoisonRow,
                3 => FaultKind::ForcedNumerical,
                4 => FaultKind::Wedge { rounds: 1 + rng.below(3) as u32 },
                _ => FaultKind::CorruptInverse { factor: rng.range(1.5, 4.0) },
            };
            plan.push(shard, round, kind);
        }
        plan
    }

    /// All scheduled faults, in insertion order.
    pub fn faults(&self) -> &[ScheduledFault] {
        &self.faults
    }

    /// Total scheduled faults.
    pub fn len(&self) -> usize {
        self.faults.len()
    }

    /// True when nothing is scheduled.
    pub fn is_empty(&self) -> bool {
        self.faults.is_empty()
    }

    /// Faults firing on `(shard, round)`.
    pub fn firing(&self, shard: usize, round: u64) -> impl Iterator<Item = &ScheduledFault> {
        self.faults
            .iter()
            .filter(move |f| f.shard == shard && f.round == round)
    }

    /// Count of scheduled faults matching a predicate — used by chaos
    /// tests to check observed counters against the injected plan.
    pub fn count_where(&self, pred: impl Fn(&ScheduledFault) -> bool) -> usize {
        self.faults.iter().filter(|f| pred(f)).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_seed_same_plan() {
        let a = FaultPlan::random(42, 4, 20, 10);
        let b = FaultPlan::random(42, 4, 20, 10);
        assert_eq!(a.faults(), b.faults());
        assert_eq!(a.len(), 10);
        let c = FaultPlan::random(43, 4, 20, 10);
        assert_ne!(a.faults(), c.faults(), "different seeds must differ");
    }

    #[test]
    fn firing_filters_by_cell() {
        let mut p = FaultPlan::new(0);
        p.push(0, 3, FaultKind::NanRow)
            .push(1, 3, FaultKind::ForcedNumerical)
            .push(0, 3, FaultKind::InfRow)
            .push(0, 4, FaultKind::PoisonRow);
        let at: Vec<_> = p.firing(0, 3).map(|f| f.kind).collect();
        assert_eq!(at, vec![FaultKind::NanRow, FaultKind::InfRow]);
        assert_eq!(p.firing(2, 3).count(), 0);
        assert_eq!(p.count_where(|f| f.shard == 0), 3);
        assert!(!p.is_empty());
    }

    #[test]
    fn kill_point_catalogue_is_exhaustive_and_distinct() {
        for (i, a) in KillPoint::ALL.iter().enumerate() {
            for b in &KillPoint::ALL[i + 1..] {
                assert_ne!(a, b, "KillPoint::ALL carries a duplicate");
            }
        }
        // the match is the exhaustiveness proof: adding a variant without
        // extending ALL fails to compile here
        for p in KillPoint::ALL {
            match p {
                KillPoint::WalAppendTorn
                | KillPoint::WalAppendFull
                | KillPoint::WalFsync
                | KillPoint::SnapTmpTorn
                | KillPoint::SnapTmpFull
                | KillPoint::SnapTmpFsync
                | KillPoint::SnapRename
                | KillPoint::SnapDirFsync
                | KillPoint::SnapNewSegment
                | KillPoint::SnapGc => {}
            }
        }
    }

    #[test]
    fn random_plan_stays_on_grid() {
        let p = FaultPlan::random(7, 3, 15, 40);
        for f in p.faults() {
            assert!(f.shard < 3);
            assert!(f.round < 15);
            if let FaultKind::Wedge { rounds } = f.kind {
                assert!((1..=3).contains(&rounds));
            }
            if let FaultKind::CorruptInverse { factor } = f.kind {
                assert!((1.5..4.0).contains(&factor));
            }
        }
    }
}
