//! **API stub** for the `xla` crate (the xla_extension 0.5.1 wrapper) used
//! by mikrr's `pjrt` feature.
//!
//! The real crate is not in the offline set, but the PJRT runtime code in
//! `mikrr/src/runtime/pjrt.rs` must not rot unchecked behind its feature
//! gate. This stub mirrors **exactly the surface that code compiles
//! against** — types, method signatures, error plumbing — so
//! `cargo check --features pjrt` keeps the real runtime honest without
//! network access or native XLA libraries.
//!
//! At run time every fallible entry point fails: [`PjRtClient::cpu`]
//! returns an error, so `PjrtRuntime::load_dir` fails and `HybridExec`
//! falls back to the native f64 path — the same observable behavior as a
//! feature-off build, but with the real runtime code compiled.
//!
//! To execute real AOT artifacts, repoint mikrr's `xla` path dependency at
//! the vendored xla_extension wrapper (see `rust/Cargo.toml` and
//! /opt/xla-example); this stub keeps signature parity with that wrapper,
//! so the swap is a one-line manifest change.

use std::fmt;

/// Stub error returned by every fallible entry point.
#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla API stub: {}", self.0)
    }
}

impl std::error::Error for Error {}

fn stub_err<T>(what: &str) -> Result<T, Error> {
    Err(Error(format!(
        "{what} unavailable (API stub — vendor the real xla_extension wrapper to run AOT \
         artifacts)"
    )))
}

/// Host-side literal (mirrors `xla::Literal`).
pub struct Literal {}

impl Literal {
    /// Rank-1 f32 literal.
    pub fn vec1(_data: &[f32]) -> Self {
        Self {}
    }

    /// Reshape to `dims`.
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
        stub_err("Literal::reshape")
    }

    /// The literal's array shape.
    pub fn array_shape(&self) -> Result<ArrayShape, Error> {
        stub_err("Literal::array_shape")
    }

    /// Copy out as a host vector.
    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        stub_err("Literal::to_vec")
    }

    /// Decompose a tuple literal.
    pub fn to_tuple(&self) -> Result<Vec<Literal>, Error> {
        stub_err("Literal::to_tuple")
    }
}

/// Array shape: element dims (mirrors `xla::ArrayShape`).
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    /// Dimension sizes.
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Parsed HLO module (mirrors `xla::HloModuleProto`).
pub struct HloModuleProto {}

impl HloModuleProto {
    /// Parse HLO text from a file.
    pub fn from_text_file(path: &str) -> Result<Self, Error> {
        stub_err(&format!("HloModuleProto::from_text_file({path:?})"))
    }
}

/// A computation handle (mirrors `xla::XlaComputation`).
pub struct XlaComputation {}

impl XlaComputation {
    /// Wrap a parsed module.
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        Self {}
    }
}

/// A PJRT client (mirrors `xla::PjRtClient`).
pub struct PjRtClient {}

impl PjRtClient {
    /// CPU client — **always fails in the stub**, which is what keeps
    /// `PjrtRuntime::load_dir` on the native-fallback path.
    pub fn cpu() -> Result<Self, Error> {
        stub_err("PjRtClient::cpu")
    }

    /// Compile a computation.
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        stub_err("PjRtClient::compile")
    }
}

/// A device buffer (mirrors `xla::PjRtBuffer`).
pub struct PjRtBuffer {}

impl PjRtBuffer {
    /// Copy the buffer back to a host literal.
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        stub_err("PjRtBuffer::to_literal_sync")
    }
}

/// A compiled executable (mirrors `xla::PjRtLoadedExecutable`).
pub struct PjRtLoadedExecutable {}

impl PjRtLoadedExecutable {
    /// Execute with the given argument literals.
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        stub_err("PjRtLoadedExecutable::execute")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_entry_point_fails_descriptively() {
        let e = match PjRtClient::cpu() {
            Err(e) => e,
            Ok(_) => panic!("stub client must fail"),
        };
        assert!(e.to_string().contains("stub"), "{e}");
        let lit = Literal::vec1(&[1.0, 2.0]);
        assert!(lit.reshape(&[2]).is_err());
        assert!(lit.array_shape().is_err());
        assert!(lit.to_vec::<f32>().is_err());
        assert!(lit.to_tuple().is_err());
        assert!(HloModuleProto::from_text_file("/nonexistent").is_err());
    }
}
