//! Paper-table benchmarks: one bench id per table/figure of the paper's
//! evaluation (DESIGN.md §5 experiment index).
//!
//! Run all:            cargo bench --bench paper_tables
//! One cell:           cargo bench --bench paper_tables -- --filter ecg_poly2
//! Quick smoke:        cargo bench --bench paper_tables -- --quick
//! Paper-scale ECG:    MIKRR_FULL_SCALE=1 cargo bench --bench paper_tables
//!
//! Each cell runs the three strategies over 10 rounds of +4/−2 (the exact
//! protocol of §V), prints the per-round log10 table and the cumulative
//! curves, and asserts the qualitative result (multiple < single < none,
//! identical accuracy).

use mikrr::benchlib::Bencher;
use mikrr::config::Space;
use mikrr::coordinator::experiment::{run_kbr, run_krr, Strategy};
use mikrr::data::synth;
use mikrr::data::Dataset;
use mikrr::kbr::KbrHyper;
use mikrr::kernels::Kernel;

struct Sizes {
    ecg_train: usize,
    drt_train: usize,
    drt_dim: usize,
    rounds: usize,
}

fn sizes(quick: bool) -> Sizes {
    if std::env::var("MIKRR_FULL_SCALE").is_ok() {
        // paper dims: ECG 83 226 train (of 104 033), DRT 640 of 800, M=1e6
        Sizes { ecg_train: 83_226, drt_train: 640, drt_dim: 1_000_000, rounds: 10 }
    } else if quick {
        Sizes { ecg_train: 600, drt_train: 200, drt_dim: 1_500, rounds: 3 }
    } else {
        Sizes { ecg_train: 3_000, drt_train: 640, drt_dim: 8_000, rounds: 10 }
    }
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let quick = args.iter().any(|a| a == "--quick");
    let mut b = Bencher::from_args(args);
    let sz = sizes(quick);
    let seed = 7u64;

    eprintln!(
        "generating datasets (ECG n={}, DRT n={} M={})...",
        sz.ecg_train, sz.drt_train, sz.drt_dim
    );
    let need_ecg = b.enabled("ecg");
    let need_drt = b.enabled("drt");
    let ecg: Option<Dataset> = need_ecg
        .then(|| synth::ecg_like(sz.ecg_train + sz.rounds * 4 + 1_000, 21, seed));
    let drt: Option<Dataset> = need_drt
        .then(|| synth::drt_like(sz.drt_train + sz.rounds * 4 + 120, sz.drt_dim, 0.01, seed));

    let strategies = [Strategy::Multiple, Strategy::Single, Strategy::None];

    // ----- Tables IV-VIII / Figures 2-6 (KRR) -----
    let ecg_n = sz.ecg_train;
    let drt_n = sz.drt_train;
    let krr_cells: [(&str, bool, Kernel, Space, usize); 5] = [
        ("ecg_poly2 [Table IV / Fig 2]", true, Kernel::poly(2, 1.0), Space::Intrinsic, ecg_n),
        ("ecg_poly3 [Table V / Fig 3]", true, Kernel::poly(3, 1.0), Space::Intrinsic, ecg_n),
        ("drt_poly2 [Table VI / Fig 4]", false, Kernel::poly(2, 1.0), Space::Empirical, drt_n),
        ("drt_poly3 [Table VII / Fig 5]", false, Kernel::poly(3, 1.0), Space::Empirical, drt_n),
        ("drt_rbf [Table VIII / Fig 6]", false, Kernel::rbf_radius(50.0), Space::Empirical, drt_n),
    ];
    let mut krr_summaries = Vec::new();
    for (id, is_ecg, kernel, space, train) in krr_cells {
        if !b.enabled(id) {
            continue;
        }
        let data = if is_ecg { ecg.as_ref().unwrap() } else { drt.as_ref().unwrap() };
        let mut report = None;
        b.bench_once(id, || {
            report = Some(
                run_krr(data, &kernel, 0.5, space, train, sz.rounds, 4, 2, seed, &strategies)
                    .expect("experiment cell failed"),
            );
        });
        let report = report.unwrap();
        println!("{}", report.record.render_table(&format!(
            "{id}: per-round log10 s (acc {:.2}%, strategies agree: {})",
            100.0 * report.accuracy, report.strategies_agree
        )));
        println!("{}", report.record.render_curves(&format!("{id} cumulative")));
        assert!(report.strategies_agree, "{id}: accuracy invariance violated");
        assert!(
            report.record.mean_seconds("multiple") < report.record.mean_seconds("single"),
            "{id}: multiple not faster than single"
        );
        krr_summaries.push((
            id,
            report.record.mean_seconds("multiple"),
            report.record.mean_seconds("single"),
            report.record.mean_seconds("none"),
            report.record.improvement_fold("multiple", "single"),
        ));
    }
    if !krr_summaries.is_empty() {
        println!("\n=== Table IX: KRR average computational time in a single round ===");
        println!(
            "{:<34} {:>12} {:>12} {:>12} {:>13}",
            "cell", "multiple(s)", "single(s)", "none(s)", "fold(mvs s)"
        );
        for (id, m, s, n, f) in &krr_summaries {
            println!("{id:<34} {m:>12.6} {s:>12.6} {n:>12.6} {f:>12.2}x");
        }
    }

    // ----- Tables X-XI / Figures 7-8 (KBR) -----
    let mut kbr_summaries = Vec::new();
    for (id, kernel) in [
        ("kbr_ecg_poly2 [Table X / Fig 7]", Kernel::poly(2, 1.0)),
        ("kbr_ecg_poly3 [Table XI / Fig 8]", Kernel::poly(3, 1.0)),
    ] {
        if !b.enabled(id) {
            continue;
        }
        let data = ecg.as_ref().expect("ecg needed for kbr cells");
        let mut report = None;
        b.bench_once(id, || {
            report = Some(
                run_kbr(
                    data,
                    &kernel,
                    KbrHyper::default(),
                    sz.ecg_train,
                    sz.rounds,
                    4,
                    2,
                    seed,
                    true,
                )
                .expect("kbr cell failed"),
            );
        });
        let report = report.unwrap();
        println!("{}", report.record.render_table(&format!(
            "{id}: per-round log10 s (posteriors agree: {})",
            report.strategies_agree
        )));
        println!("{}", report.record.render_curves(&format!("{id} cumulative")));
        assert!(report.strategies_agree, "{id}: posterior mismatch");
        kbr_summaries.push((
            id,
            report.record.mean_seconds("multiple"),
            report.record.mean_seconds("single"),
            report.record.improvement_fold("multiple", "single"),
        ));
    }
    if !kbr_summaries.is_empty() {
        println!("\n=== Table XII: KBR average computational time in a single round ===");
        println!("{:<34} {:>12} {:>12} {:>13}", "cell", "multiple(s)", "single(s)", "fold");
        for (id, m, s, f) in &kbr_summaries {
            println!("{id:<34} {m:>12.6} {s:>12.6} {f:>12.2}x");
        }
    }

    // machine-readable report: cell wall-times plus per-strategy means
    // (round latency summaries live in each cell's RoundRecord; the JSON
    // carries the cross-PR comparable aggregates)
    let mut extras: Vec<(String, f64)> = Vec::new();
    for &(id, m, s, n, f) in &krr_summaries {
        let key = id.split_whitespace().next().unwrap_or(id);
        extras.push((format!("{key}.multiple_s"), m));
        extras.push((format!("{key}.single_s"), s));
        extras.push((format!("{key}.none_s"), n));
        extras.push((format!("{key}.fold"), f));
    }
    for &(id, m, s, f) in &kbr_summaries {
        let key = id.split_whitespace().next().unwrap_or(id);
        extras.push((format!("{key}.multiple_s"), m));
        extras.push((format!("{key}.single_s"), s));
        extras.push((format!("{key}.fold"), f));
    }
    let extras_ref: Vec<(&str, f64)> =
        extras.iter().map(|(k, v)| (k.as_str(), *v)).collect();
    if let Err(e) = b.write_json("BENCH_paper_tables.json", &extras_ref) {
        eprintln!("(could not write BENCH_paper_tables.json: {e})");
    }

    println!("\npaper_tables done ({} cells).", b.results.len());
}
