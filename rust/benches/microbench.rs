//! Micro/ablation benchmarks (beyond the paper's tables):
//!
//! * `woodbury_batch_sweep`  — rank-|H| update cost vs |H| (validates the
//!   §II.B rule: batching beats |H| rank-1 updates; fresh inverse wins
//!   only as |H| -> J).
//! * `shrink_vs_recompute`   — eq. (29) shrink vs fresh inverse as |R|
//!   grows (validates the §III.B rule).
//! * `gram_block_sweep`      — Gram construction cost vs block size.
//! * `aot_vs_native`         — the canonical woodbury update through the
//!   AOT artifact vs the native f64 path.
//! * `incplace`              — the in-place maintained-inverse engine vs
//!   the seed-equivalent allocating path (BENCH_incplace.json: round
//!   latency p50/p99, allocations per round, speedup).
//! * `core/*`                — the SIMD-packed compute core: J=2024 SPD
//!   factorization (blocked vs scalar reference), symmetric Gram through
//!   the SYRK route vs the general path, packed GEMM, blocked LU, packed
//!   NT vs the row-dot fallback (`core/gemm_nt_packed_vs_axpy`), the SYRK
//!   macro-kernel vs the dot-tile path (`core/syrk_macro_1024`), blocked
//!   TRSM vs per-column substitution (`core/trsm_blocked_vs_scalar`), and
//!   the packed parallel LU panel vs its serial reference at the J=2024
//!   bootstrap height (`core/lu_panel_packed`). The blocked-vs-naive pairs
//!   feed `speedup_*` extras; a child re-run of the same section at full
//!   thread count (`BENCH_microbench_mt.json`) feeds the `mt_speedup_*`
//!   extras, so BENCH_microbench.json reports both the algorithmic and the
//!   multi-threaded gains. (`speedup_lu_panel_packed` is the one headline
//!   computed serial-reference vs full-thread child: the packed panel's
//!   win IS the parallelism.)
//! * `serve/*`             — the sharded serving layer: B=64 per-request
//!   uncertainty GEMVs vs one micro-batched BLAS-3 predict round
//!   (`serve/microbatch_predict`, headline `speedup_serve_microbatch` —
//!   perf-gated in CI), and the K=1 vs K=4 empirical-space shard update
//!   round (`serve/shard_round`, `speedup_serve_shard_k4`: the same
//!   logical +4/−4 round on one N=512 inverse vs four (N/4)² shards), and
//!   the fully instrumented shard round vs the same round against a
//!   disabled registry (`serve/telemetry_overhead`, headline
//!   `overhead_telemetry_round` — perf-gated in CI at <= 1.03x).
//! * `multi/*`             — multi-output targets + duplicate folding
//!   (ISSUE 6): one engine with a (J, 8) coefficient block answering a
//!   256-row query as one packed GEMM vs 8 sequential D=1 GEMV engines
//!   (`multi/predict_d8`, headline `speedup_multi_output_predict` —
//!   perf-gated in CI), and the 50%-repeat hot-sensor stream where folded
//!   rounds replace duplicate inserts with rank-1 multiplicity bumps
//!   (`multi/fold_hot_sensors`, tracked `speedup_fold_hot_sensors`). The
//!   run's target dim D and fold ratio are recorded in the env block.
//! * `health/*`            — the per-round residual probe (4 sampled
//!   columns against the maintained inverse) vs the full refit it gates
//!   (`health/probe_residual`, tracked `speedup_health_probe_vs_refit`):
//!   quantifies that always-on health checking is orders cheaper than the
//!   recovery it triggers.
//! * `persist/*`           — the durability hot path (ISSUE 8): a 4-event
//!   WAL batch append (frame + CRC + fsync) vs the full N=600 engine
//!   snapshot it amortizes (`persist/durability`, tracked
//!   `speedup_persist_wal_vs_snapshot` — fsync-bound, so reported but not
//!   perf-gated).
//! * `net/*`               — the socket serving front-end (ISSUE 9): a
//!   sustained mixed predict/update storm over loopback TCP through the
//!   epoll reactor (`net/storm`, tracked `sustained_rps` and
//!   `net_storm_p99_us`): 4 client threads, 7:1 predict:update mix, shed
//!   requests retried after the server's hint — the end-to-end serving
//!   capacity including framing, syscalls, and window batching.
//! * `featmap`, `gemm`, `spd_inverse` — substrate hot spots.
//!
//! Run: cargo bench --bench microbench [-- --filter <id>] [-- --quick]
//!
//! Results are also written to `BENCH_microbench.json` (and the in-place
//! engine comparison to `BENCH_incplace.json`) so the perf trajectory is
//! tracked across PRs; every report carries an `env` block (threads,
//! MIKRR_THREADS, build profile) for cross-run comparability.
//!
//! Runs single-threaded by default (exported `MIKRR_THREADS=1` unless the
//! caller sets it): latency percentiles are stable, the allocating-vs-
//! in-place comparison is apples to apples, and the allocations-per-round
//! measurement reflects the engines' contract rather than pool dispatch.
//! The multi-threaded picture comes from the `core/*` child process, which
//! runs with the override removed (all cores, capped by the pool).

use mikrr::benchlib::{black_box, Bencher};
use mikrr::kernels::Kernel;
use mikrr::krr::intrinsic::IntrinsicKrr;
use mikrr::krr::KrrModel;
use mikrr::linalg::solve::{
    cholesky, cholesky_naive, lu_decompose, lu_decompose_naive, spd_inverse,
};
use mikrr::linalg::woodbury::{bordered_shrink, incdec, incdec_into, sub_matrix, IncDecWork};
use mikrr::linalg::Mat;
use mikrr::runtime::HybridExec;
use mikrr::testutil::{random_mat, random_spd};
use mikrr::util::alloc_counter::{self, CountingAlloc};
use mikrr::util::prng::Rng;

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

/// The compute-core section: shared between the (default) single-threaded
/// parent and the multi-threaded child re-run.
fn core_benches(b: &mut Bencher, rng: &mut Rng) {
    // (a) J=2024 SPD factorization (the paper's poly3 intrinsic dim):
    // blocked right-looking Cholesky vs the scalar reference
    if b.enabled("core/spd_factor_2024_naive") || b.enabled("core/spd_factor_2024_blocked") {
        let spd_big = random_spd(rng, 2024, 50.0);
        b.bench("core/spd_factor_2024_naive", || {
            black_box(cholesky_naive(&spd_big).unwrap());
        });
        b.bench("core/spd_factor_2024_blocked", || {
            black_box(cholesky(&spd_big).unwrap());
        });
    }
    // (b) symmetric Gram construction: general path (cross-gram +
    // symmetrize, the PR 1 route) vs the SYRK route
    let x = random_mat(rng, 512, 21, 0.5);
    for kernel in [Kernel::poly(2, 1.0), Kernel::rbf_radius(50.0)] {
        let name = match &kernel {
            Kernel::Poly { .. } => "poly2",
            Kernel::Rbf { .. } => "rbf",
            _ => "other",
        };
        b.bench(&format!("core/gram_sym_general_512_{name}"), || {
            let mut k = mikrr::kernels::gram::gram(&kernel, &x, &x);
            k.symmetrize();
            black_box(k);
        });
        b.bench(&format!("core/gram_sym_syrk_512_{name}"), || {
            black_box(mikrr::kernels::gram::gram_symmetric(&kernel, &x));
        });
    }
    // packed GEMM at a cache-hostile cube
    if b.enabled("core/gemm_512x512x512") {
        let a = random_mat(rng, 512, 512, 1.0);
        let c = random_mat(rng, 512, 512, 1.0);
        b.bench("core/gemm_512x512x512", || {
            black_box(mikrr::linalg::gemm::matmul(&a, &c).unwrap());
        });
    }
    // blocked LU vs the scalar reference (general baselines / determinants)
    if b.enabled("core/lu_factor_1024_naive") || b.enabled("core/lu_factor_1024_blocked") {
        let g = {
            let mut g = random_mat(rng, 1024, 1024, 1.0);
            g.add_diag(8.0).unwrap();
            g
        };
        b.bench("core/lu_factor_1024_naive", || {
            black_box(lu_decompose_naive(&g).unwrap());
        });
        b.bench("core/lu_factor_1024_blocked", || {
            black_box(lu_decompose(&g).unwrap());
        });
    }
    // (c) NT product over the dispatch crossover: the row-dot fallback vs
    // the packed transpose-aware engine (same shape, same thread count)
    if b.enabled("core/gemm_nt_packed_vs_axpy") {
        use mikrr::linalg::gemm::{matmul_nt_dots_into, matmul_nt_into};
        let a = random_mat(rng, 384, 512, 0.5);
        let bt = random_mat(rng, 320, 512, 0.5);
        let mut c = Mat::default();
        b.bench("core/gemm_nt_packed_vs_axpy/axpy_384x320_k512", || {
            matmul_nt_dots_into(&a, &bt, &mut c).unwrap();
            black_box(&c);
        });
        b.bench("core/gemm_nt_packed_vs_axpy/packed_384x320_k512", || {
            matmul_nt_into(&a, &bt, &mut c).unwrap();
            black_box(&c);
        });
    }
    // (d) SYRK macro-kernel vs the 4×4 dot-tile path at a Gram-build shape
    if b.enabled("core/syrk_macro_1024") {
        use mikrr::linalg::gemm::{syrk_into, syrk_tiled_into};
        let a = random_mat(rng, 1024, 192, 0.5);
        let mut c = Mat::default();
        b.bench("core/syrk_macro_1024/tiled", || {
            syrk_tiled_into(1.0, &a, 0.0, &mut c).unwrap();
            black_box(&c);
        });
        b.bench("core/syrk_macro_1024/macro", || {
            syrk_into(1.0, &a, 0.0, &mut c).unwrap();
            black_box(&c);
        });
    }
    // (e) blocked TRSM vs per-column scalar substitution (the SPD-inverse
    // inner loop before/after this PR)
    if b.enabled("core/trsm_blocked_vs_scalar") {
        use mikrr::linalg::gemm::trsm_lower_into;
        use mikrr::linalg::solve::forward_sub;
        let spd = random_spd(rng, 768, 50.0);
        let l = cholesky(&spd).unwrap();
        let b0 = random_mat(rng, 768, 768, 0.5);
        let mut col = vec![0.0; 768];
        b.bench("core/trsm_blocked_vs_scalar/scalar_768", || {
            for j in 0..768 {
                for (i, c) in col.iter_mut().enumerate() {
                    *c = b0[(i, j)];
                }
                forward_sub(&l, &mut col).unwrap();
            }
            black_box(&col);
        });
        let mut x = Mat::default();
        b.bench("core/trsm_blocked_vs_scalar/blocked_768", || {
            x.resize_scratch(768, 768);
            x.as_mut_slice().copy_from_slice(b0.as_slice());
            trsm_lower_into(&l, false, &mut x).unwrap();
            black_box(&x);
        });
    }
    // (f) the LU panel: packed parallel pivot search + ger_panel fused
    // scale/rank-1 updates vs the serial scalar reference, at the J=2024
    // bootstrap panel height (the shape the blocked factorization hands
    // the panel machinery at the paper's poly3 intrinsic dim). The packed
    // side's win is parallelism by design, so the headline speedup extra
    // pairs the serial reference against the full-thread child run (see
    // main).
    if b.enabled("core/lu_panel_packed") {
        use mikrr::linalg::solve::{lu_panel_factor, lu_panel_factor_scalar};
        let a0 = random_mat(rng, 2024, 64, 1.0);
        let mut w = Mat::default();
        b.bench("core/lu_panel_packed/scalar_2024x64", || {
            w.resize_scratch(2024, 64);
            w.as_mut_slice().copy_from_slice(a0.as_slice());
            black_box(lu_panel_factor_scalar(&mut w, 64).unwrap());
        });
        b.bench("core/lu_panel_packed/packed_2024x64", || {
            w.resize_scratch(2024, 64);
            w.as_mut_slice().copy_from_slice(a0.as_slice());
            black_box(lu_panel_factor(&mut w, 64).unwrap());
        });
    }
}

/// Pull `"mean_s"` for a named benchmark out of one of our own
/// `BENCH_*.json` reports (hand-rolled — the offline crate set has no
/// serde, and the format is ours).
fn bench_mean_from_json(text: &str, name: &str) -> Option<f64> {
    let tag = format!("\"name\": \"{name}\"");
    let at = text.find(&tag)?;
    let rest = &text[at + tag.len()..];
    let key = "\"mean_s\": ";
    let kat = rest.find(key)?;
    let tail = &rest[kat + key.len()..];
    let end = tail.find([',', '}'])?;
    tail[..end].trim().parse().ok()
}

/// First numeric value following `key` (for the env block's thread count).
fn json_number_after(text: &str, key: &str) -> Option<f64> {
    let at = text.find(key)?;
    let tail = &text[at + key.len()..];
    let end = tail.find([',', '}', '\n'])?;
    tail[..end].trim().parse().ok()
}

fn main() {
    let mt_child = std::env::var("MIKRR_BENCH_MT_CHILD").is_ok();
    if !mt_child && std::env::var("MIKRR_THREADS").is_err() {
        // must happen before any parallel call: num_threads() caches
        #[allow(unused_unsafe)]
        unsafe {
            std::env::set_var("MIKRR_THREADS", "1")
        };
    }
    let mut b = Bencher::from_args(std::env::args().skip(1));
    let mut rng = Rng::new(1);

    if mt_child {
        // child mode: the compute-core section only, at full thread count
        core_benches(&mut b, &mut rng);
        let extras = [("threads", mikrr::par::num_threads() as f64)];
        if let Err(e) = b.write_json("BENCH_microbench_mt.json", &extras) {
            eprintln!("(could not write BENCH_microbench_mt.json: {e})");
        }
        println!(
            "\nmt child done ({} benchmarks, {} threads).",
            b.results.len(),
            mikrr::par::num_threads()
        );
        return;
    }

    // ---- woodbury batch-size sweep (J = 253, the paper's poly2 dim) ----
    let j = 253;
    let s_inv = spd_inverse(&random_spd(&mut rng, j, 60.0)).unwrap();
    for h in [1usize, 2, 4, 6, 8, 16, 32, 64] {
        let phi = random_mat(&mut rng, j, h, 0.05);
        let signs = vec![1.0; h];
        b.bench(&format!("woodbury_batch_sweep/J253_H{h}"), || {
            black_box(incdec(&s_inv, &phi, &signs).unwrap());
        });
    }
    // compare: H rank-1 updates vs one rank-H (the paper's core lever)
    {
        let h = 6;
        let phi = random_mat(&mut rng, j, h, 0.05);
        let signs = vec![1.0; h];
        b.bench("woodbury_one_rank6", || {
            black_box(incdec(&s_inv, &phi, &signs).unwrap());
        });
        b.bench("woodbury_six_rank1", || {
            let mut s = s_inv.clone();
            for k in 0..h {
                let col = Mat::from_vec(j, 1, phi.col(k)).unwrap();
                s = incdec(&s, &col, &[1.0]).unwrap();
            }
            black_box(s);
        });
        b.bench("fresh_inverse_J253", || {
            black_box(spd_inverse(&random_spd(&mut rng, j, 60.0)).unwrap());
        });
    }

    // ---- empirical shrink vs recompute (N = 400) ----
    let n = 400;
    let q = random_spd(&mut rng, n, 40.0);
    let q_inv = spd_inverse(&q).unwrap();
    for r in [2usize, 8, 32, 128, 300] {
        let rem: Vec<usize> = (0..r).map(|i| i * (n / r)).collect();
        b.bench(&format!("shrink_vs_recompute/shrink_R{r}"), || {
            black_box(bordered_shrink(&q_inv, &rem).unwrap());
        });
        let keep: Vec<usize> = (0..n).filter(|i| !rem.contains(i)).collect();
        b.bench(&format!("shrink_vs_recompute/recompute_R{r}"), || {
            let sub = sub_matrix(&q, &keep, &keep);
            black_box(spd_inverse(&sub).unwrap());
        });
    }

    // ---- gram block sweep ----
    let x = random_mat(&mut rng, 512, 21, 0.5);
    for kernel in [Kernel::poly(2, 1.0), Kernel::rbf_radius(50.0)] {
        let name = match &kernel {
            Kernel::Poly { .. } => "poly2",
            Kernel::Rbf { .. } => "rbf",
            _ => "other",
        };
        b.bench(&format!("gram_block_sweep/{name}_512x512"), || {
            black_box(kernel.gram_symmetric(&x));
        });
    }

    // ---- AOT artifact vs native (canonical shapes) ----
    {
        let ex = HybridExec::auto();
        let phi = random_mat(&mut rng, j, 6, 0.05);
        let signs = [1.0, 1.0, 1.0, 1.0, -1.0, -1.0];
        if ex.has_aot() {
            b.bench("aot_vs_native/woodbury_aot_J253_H6", || {
                black_box(ex.woodbury_incdec(&s_inv, &phi, &signs).unwrap());
            });
        } else {
            eprintln!("(aot_vs_native: artifacts not found, skipping AOT side)");
        }
        b.bench("aot_vs_native/woodbury_native_J253_H6", || {
            black_box(ex.woodbury_native(&s_inv, &phi, &signs).unwrap());
        });
    }

    // ---- full-scale sparse DRT (paper M=1e6; dense would be 6.4 GB) ----
    if b.enabled("sparse_full_scale") {
        let (xs, ys) = mikrr::data::synth::drt_like_sparse(160, 1_000_000, 0.009, 3);
        b.bench("sparse_full_scale/gram_160x160_M1e6", || {
            black_box(xs.gram(&xs, &Kernel::poly(2, 1.0)).unwrap());
        });
        let poly2 = Kernel::poly(2, 1.0);
        let mut model =
            mikrr::krr::empirical_sparse::SparseEmpiricalKrr::fit(&xs, &ys, &poly2, 0.5).unwrap();
        // cycle fresh batches (+4/−4 keeps n constant and the set duplicate-
        // free: each inserted row is removed ~40 iterations later, long
        // before its batch recurs)
        let pool: Vec<_> = (0..50)
            .map(|k| mikrr::data::synth::drt_like_sparse(4, 1_000_000, 0.009, 100 + k))
            .collect();
        let mut iter = 0usize;
        b.bench("sparse_full_scale/incdec_plus4_minus4", || {
            let (xc, yc) = &pool[iter % pool.len()];
            model.inc_dec(xc, yc, &[0, 1, 2, 3]).unwrap();
            iter += 1;
        });
    }

    // ---- in-place maintained-inverse engine (BENCH_incplace.json) ----
    // Baseline = the seed's allocating round: a fresh (J, J) copy of the
    // maintained inverse plus cold T/W/core buffers every call. In-place =
    // the same rank-6 update written into the live buffer with a warm
    // workspace. Signs +3/−3 over duplicated columns make each round an
    // exact identity, so the in-place state stays perfectly conditioned
    // over any number of iterations.
    let mut allocs_per_round = -1.0f64;
    {
        let phi3 = random_mat(&mut rng, j, 3, 0.05);
        let phi6 = phi3.hcat(&phi3).unwrap();
        let signs = [1.0, 1.0, 1.0, -1.0, -1.0, -1.0];
        b.bench("incplace/incdec_alloc_J253_H6", || {
            black_box(incdec(&s_inv, &phi6, &signs).unwrap());
        });
        let mut s_live = s_inv.clone();
        let mut work = IncDecWork::default();
        incdec_into(&mut s_live, &phi6, &signs, &mut work).unwrap(); // warm
        b.bench("incplace/incdec_inplace_J253_H6", || {
            incdec_into(&mut s_live, &phi6, &signs, &mut work).unwrap();
        });

        // model-level steady state at the paper's J=253: +4/−4 rounds
        if b.enabled("incplace/intrinsic_round_J253") {
            let d = mikrr::data::synth::ecg_like(600, 21, 9);
            let mut model =
                IntrinsicKrr::fit(&d.x, &d.y, &Kernel::poly(2, 1.0), 0.5).unwrap();
            let pool: Vec<_> = (0..16)
                .map(|k| mikrr::data::synth::ecg_like(4, 21, 50 + k))
                .collect();
            let rem = [0usize, 1, 2, 3];
            let mut iter = 0usize;
            // warm the workspaces, then count allocations outside the timer
            for _ in 0..4 {
                let batch = &pool[iter % pool.len()];
                model.inc_dec(&batch.x, &batch.y, &rem).unwrap();
                iter += 1;
            }
            let a0 = alloc_counter::count();
            let counted = 20usize;
            for _ in 0..counted {
                let batch = &pool[iter % pool.len()];
                model.inc_dec(&batch.x, &batch.y, &rem).unwrap();
                iter += 1;
            }
            allocs_per_round =
                (alloc_counter::count() - a0) as f64 / counted as f64;
            b.bench("incplace/intrinsic_round_J253", || {
                let batch = &pool[iter % pool.len()];
                model.inc_dec(&batch.x, &batch.y, &rem).unwrap();
                iter += 1;
            });
        }
    }

    // ---- substrate hot spots ----
    {
        let table = Kernel::poly(2, 1.0).feature_table(21).unwrap();
        let xb = random_mat(&mut rng, 256, 21, 0.5);
        b.bench("featmap/poly2_256x21", || {
            black_box(table.map(&xb));
        });
        let a = random_mat(&mut rng, 253, 253, 1.0);
        let c = random_mat(&mut rng, 253, 253, 1.0);
        b.bench("gemm/253x253x253", || {
            black_box(mikrr::linalg::gemm::matmul(&a, &c).unwrap());
        });
        let spd = random_spd(&mut rng, 253, 30.0);
        b.bench("spd_inverse/253", || {
            black_box(spd_inverse(&spd).unwrap());
        });
    }

    // ---- the SIMD-packed compute core (ISSUE 2 acceptance gates) ----
    core_benches(&mut b, &mut rng);

    // ---- serve/*: the sharded serving layer (ISSUE 5 gates) ----
    // (a) micro-batched prediction: B=64 single-row uncertainty predicts
    // (per-request covariance GEMV + per-call allocation) vs ONE 64-row
    // batched predict_into — the (J,J)·(J,64) product sits over the packed
    // dispatch crossover at the paper's J=253 (poly2, m=21)
    if b.enabled("serve/microbatch_predict") {
        use mikrr::coordinator::CoordinatorConfig;
        use mikrr::serve::{
            Placement, PredictRequest, PredictResponse, QueryKind, RouterPredictWork,
            ServeConfig, ShardRouter,
        };

        let d = mikrr::data::synth::ecg_like(600, 21, 11);
        let mut base = CoordinatorConfig::default_for(Kernel::poly(2, 1.0));
        base.outlier = None;
        base.with_uncertainty = true;
        let router = ShardRouter::bootstrap(
            &d.x,
            &d.y,
            ServeConfig { shards: 1, placement: Placement::RoundRobin, base },
        )
        .unwrap();
        let h = router.handle();
        let q = mikrr::data::synth::ecg_like(64, 21, 12);
        let reqs: Vec<PredictRequest> = (0..64)
            .map(|r| PredictRequest::new(q.x.block(r, r + 1, 0, 21), QueryKind::MeanVar))
            .collect();
        b.bench("serve/microbatch_predict/per_request_gemv_B64", || {
            for req in &reqs {
                black_box(h.query(req).unwrap());
            }
        });
        let mut work = RouterPredictWork::default();
        let mut resp = PredictResponse::default();
        let batch_req = PredictRequest::new(q.x.clone(), QueryKind::MeanVar);
        b.bench("serve/microbatch_predict/microbatch_gemm_B64", || {
            h.query_into(&batch_req, &mut resp, &mut work).unwrap();
            black_box(&resp);
        });
    }
    // (b) shard update round, empirical space (maintained state (N/K)^2
    // per shard): one fused +4/−4 on N=512 vs the same round split across
    // K=4 shards (+1/−1 each on N=128), applied sequentially — the flop
    // ratio alone is N^2·8 vs 4·(N/4)^2·2 = 16x
    if b.enabled("serve/shard_round") {
        use mikrr::config::Space;
        use mikrr::coordinator::CoordinatorConfig;
        use mikrr::serve::{Placement, ServeConfig, ShardRouter};

        let d = mikrr::data::synth::ecg_like(512, 8, 13);
        let mk_router = |k: usize| {
            let mut base = CoordinatorConfig::default_for(Kernel::poly(2, 1.0));
            base.space = Some(Space::Empirical);
            base.outlier = None;
            ShardRouter::bootstrap(
                &d.x,
                &d.y,
                ServeConfig { shards: k, placement: Placement::RoundRobin, base },
            )
            .unwrap()
        };
        // pool longer than the +4/−4 residency window (512/4 = 128
        // rounds): a row is always evicted before its batch recurs, so the
        // maintained empirical inverse never accumulates duplicate rows
        let pool: Vec<_> = (0..160)
            .map(|k| mikrr::data::synth::ecg_like(4, 8, 60 + k))
            .collect();
        let mut r1 = mk_router(1);
        let mut it1 = 0usize;
        b.bench("serve/shard_round/k1_n512_plus4_minus4", || {
            let batch = &pool[it1 % pool.len()];
            it1 += 1;
            r1.shard_mut(0)
                .apply_update(&batch.x, &batch.y, &[0, 1, 2, 3])
                .unwrap();
        });
        let mut r4 = mk_router(4);
        let mut it4 = 0usize;
        b.bench("serve/shard_round/k4_n128_plus1_minus1", || {
            let batch = &pool[it4 % pool.len()];
            it4 += 1;
            for s in 0..4 {
                let x = batch.x.block(s, s + 1, 0, 8);
                r4.shard_mut(s)
                    .apply_update(&x, &batch.y[s..s + 1], &[0])
                    .unwrap();
            }
        });
    }

    // (c) telemetry overhead (ISSUE 10): the fully instrumented shard
    // round (phase timers, registry counters, flight-recorder spans) vs
    // the identical round against a disabled registry. Gated
    // (`overhead_telemetry_round` <= 1.03): observability must cost no
    // more than 3% on the write path it observes.
    if b.enabled("serve/telemetry_overhead") {
        use mikrr::config::Space;
        use mikrr::coordinator::CoordinatorConfig;
        use mikrr::serve::{Placement, ServeConfig, ShardRouter};
        use mikrr::telemetry::Registry;
        use std::sync::Arc;

        let d = mikrr::data::synth::ecg_like(512, 8, 14);
        let mk_router = || {
            let mut base = CoordinatorConfig::default_for(Kernel::poly(2, 1.0));
            base.space = Some(Space::Empirical);
            base.outlier = None;
            ShardRouter::bootstrap(
                &d.x,
                &d.y,
                ServeConfig { shards: 1, placement: Placement::RoundRobin, base },
            )
            .unwrap()
        };
        let pool: Vec<_> = (0..160)
            .map(|k| mikrr::data::synth::ecg_like(4, 8, 70 + k))
            .collect();
        let mut live = mk_router();
        let mut it_on = 0usize;
        b.bench("serve/telemetry_overhead/instrumented_round_n512", || {
            let batch = &pool[it_on % pool.len()];
            it_on += 1;
            live.shard_mut(0)
                .apply_update(&batch.x, &batch.y, &[0, 1, 2, 3])
                .unwrap();
        });
        let mut dark = mk_router();
        dark.shard_mut(0).set_telemetry(Arc::new(Registry::disabled()));
        let mut it_off = 0usize;
        b.bench("serve/telemetry_overhead/disabled_round_n512", || {
            let batch = &pool[it_off % pool.len()];
            it_off += 1;
            dark.shard_mut(0)
                .apply_update(&batch.x, &batch.y, &[0, 1, 2, 3])
                .unwrap();
        });
    }

    // ---- multi/*: multi-output targets + duplicate folding (ISSUE 6) ----
    // (a) D=8 packed predict: one engine with a (J, 8) coefficient block
    // answering a 256-row query as ONE (256, J)·(J, 8) GEMM, vs 8
    // independent D=1 engines each running a (256, J)·(J, 1) GEMV pass
    let d_out = 8usize;
    b.set_target_dim(d_out);
    b.set_fold_ratio(0.5);
    if b.enabled("multi/predict_d8") {
        use mikrr::krr::intrinsic::IntrinsicPredictWork;
        let d = mikrr::data::synth::ecg_like(600, 21, 21);
        let ym = Mat::from_fn(600, d_out, |i, c| d.y[i] * (1.0 + 0.25 * c as f64));
        let poly2 = Kernel::poly(2, 1.0);
        let packed = IntrinsicKrr::fit_multi(&d.x, &ym, &poly2, 0.5).unwrap();
        let singles: Vec<IntrinsicKrr> = (0..d_out)
            .map(|c| IntrinsicKrr::fit(&d.x, &ym.col(c), &poly2, 0.5).unwrap())
            .collect();
        let q = mikrr::data::synth::ecg_like(256, 21, 22);
        let mut work = IntrinsicPredictWork::default();
        let mut out_vec = Vec::new();
        b.bench("multi/predict_d8/sequential_gemv_x8", || {
            for s in &singles {
                s.predict_into(&q.x, &mut out_vec, &mut work).unwrap();
                black_box(&out_vec);
            }
        });
        let mut out_mat = Mat::default();
        b.bench("multi/predict_d8/packed_gemm", || {
            packed.predict_multi_into(&q.x, &mut out_mat, &mut work).unwrap();
            black_box(&out_mat);
        });
    }
    // (b) hot-sensor folding: rounds of 4 arrivals where rows 1/3 repeat a
    // stored input. The folded engine turns the two repeats into rank-1
    // multiplicity bumps and only inserts/evicts 2 rows per round; the
    // unfolded engine pays the full rank-8 Woodbury (+4/−4). Each engine
    // evicts exactly what it inserts, so both stores hold steady near
    // N=600 over any number of bench iterations (a re-inserted repeat
    // whose stored copy aged out simply folds again on the next cycle).
    if b.enabled("multi/fold_hot_sensors") {
        use mikrr::config::Space;
        use mikrr::coordinator::engine::Engine;
        let d = mikrr::data::synth::ecg_like(600, 21, 23);
        let ym = Mat::from_vec(600, 1, d.y.clone()).unwrap();
        let poly2 = Kernel::poly(2, 1.0);
        let mk = |fold: bool| {
            let mut e =
                Engine::fit_multi(&d.x, &ym, &poly2, 0.5, Space::Intrinsic, false).unwrap();
            e.set_fold_eps(if fold { Some(1e-12) } else { None });
            e
        };
        // pre-built batches: rows 0/2 fresh, rows 1/3 exact repeats of
        // stored rows 100.. (away from the head evictions)
        let fresh = mikrr::data::synth::ecg_like(256, 21, 24);
        let batches: Vec<(Mat, Mat)> = (0..64)
            .map(|r| {
                let mut xb = Mat::default();
                let mut yb = Mat::default();
                for k in 0..4 {
                    if k % 2 == 0 {
                        let i = (r * 2 + k / 2) % 256;
                        xb.push_row(fresh.x.row(i)).unwrap();
                        yb.push_row(&[fresh.y[i]]).unwrap();
                    } else {
                        let i = 100 + (r * 13 + k) % 400;
                        xb.push_row(d.x.row(i)).unwrap();
                        yb.push_row(&[d.y[i]]).unwrap();
                    }
                }
                (xb, yb)
            })
            .collect();
        let mut folded = mk(true);
        let mut itf = 0usize;
        let rem2 = [0usize, 1];
        b.bench("multi/fold_hot_sensors/folded", || {
            let (xb, yb) = &batches[itf % batches.len()];
            folded.inc_dec_multi(xb, yb, &rem2).unwrap();
            itf += 1;
        });
        let mut plain = mk(false);
        let mut itp = 0usize;
        let rem = [0usize, 1, 2, 3];
        b.bench("multi/fold_hot_sensors/unfolded", || {
            let (xb, yb) = &batches[itp % batches.len()];
            plain.inc_dec_multi(xb, yb, &rem).unwrap();
            itp += 1;
        });
    }

    // ---- health/*: numerical health probes (ISSUE 7) ----
    // the per-round residual probe (4 sampled columns: kernel/scatter row
    // + GEMV against the maintained inverse) vs the full refactorization
    // it gates — the probe must be cheap enough to run every round, the
    // refit is the recovery cost paid only on a trip
    if b.enabled("health/probe_residual") {
        use mikrr::config::Space;
        use mikrr::coordinator::engine::Engine;
        use mikrr::health::{HealthProbe, ProbeConfig};

        let d = mikrr::data::synth::ecg_like(600, 21, 31);
        let poly2 = Kernel::poly(2, 1.0);
        let mut eng =
            Engine::fit(&d.x, &d.y, &poly2, 0.5, Space::Intrinsic, false).unwrap();
        let mut probe = HealthProbe::new(ProbeConfig::default());
        probe.check(&eng).unwrap(); // warm the probe buffers
        b.bench("health/probe_residual/check4_J253", || {
            black_box(probe.check(&eng).unwrap());
        });
        b.bench("health/probe_residual/refit_J253", || {
            eng.refit().unwrap();
            black_box(eng.n_samples());
        });
    }

    // ---- persist/*: the durability hot path (ISSUE 8) ----
    // the per-round WAL append (frame + CRC + fsync) vs the full engine
    // snapshot it amortizes — the trade the `checkpoint_every` cadence
    // knob tunes. Tracked (`speedup_persist_wal_vs_snapshot`), not gated:
    // both sides are fsync-bound, so the ratio is a durability-cost
    // report, not a compute regression signal.
    if b.enabled("persist/durability") {
        use mikrr::config::Space;
        use mikrr::coordinator::engine::Engine;
        use mikrr::persist::snapshot::write_snapshot;
        use mikrr::persist::wal::Wal;
        use mikrr::persist::{EngineState, WalRecord};
        use mikrr::streaming::StreamEvent;
        use mikrr::testutil::ScratchDir;

        let dir = ScratchDir::new("bench-persist");
        let d = mikrr::data::synth::ecg_like(600, 21, 41);
        let poly2 = Kernel::poly(2, 1.0);
        let eng =
            Engine::fit(&d.x, &d.y, &poly2, 0.5, Space::Intrinsic, false).unwrap();
        let events: Vec<StreamEvent> = (0..4)
            .map(|i| StreamEvent::single(d.x.row(i).to_vec(), d.y[i], 0, i as u64))
            .collect();
        let mut wal = Wal::create(dir.path(), 0, 1).unwrap();
        let mut scratch = Vec::new();
        let mut seq = 0u64;
        b.bench("persist/durability/wal_append_batch4_n600", || {
            seq += 1;
            wal.append(&WalRecord::Batch { seq, events: events.clone() }, &mut scratch)
                .unwrap();
        });
        // constant generation: each iteration renames over the same file,
        // so the bench doesn't fill the disk with snapshot history
        b.bench("persist/durability/snapshot_n600", || {
            write_snapshot(dir.path(), 1, &EngineState::capture(&eng, 1, 1, 1)).unwrap();
            black_box(());
        });
    }

    // ---- net/*: the socket serving front-end (ISSUE 9) ----
    // sustained mixed predict/update storm over loopback TCP through the
    // epoll reactor: 4 client threads, 7:1 predict:update mix, shed
    // requests retried after the server's hint. Tracked (`sustained_rps`),
    // not ratio-gated: the figure is an end-to-end serving-capacity report
    // (framing + syscalls + window batching), not a compute kernel.
    let mut net_storm: Option<(f64, f64)> = None;
    if b.enabled("net/storm") {
        use mikrr::coordinator::CoordinatorConfig;
        use mikrr::net::{Frame, NetClient, NetConfig, NetServer};
        use mikrr::serve::{
            Placement, PredictRequest, QueryKind, ServeConfig, ShardRouter,
        };
        use mikrr::streaming::StreamEvent;
        use std::time::{Duration, Instant};

        let d = mikrr::data::synth::ecg_like(600, 21, 51);
        let mut base = CoordinatorConfig::default_for(Kernel::poly(2, 1.0));
        base.outlier = None;
        base.with_uncertainty = true;
        let mut router = ShardRouter::bootstrap(
            &d.x,
            &d.y,
            ServeConfig { shards: 1, placement: Placement::RoundRobin, base },
        )
        .unwrap();
        let (server, rx) =
            NetServer::spawn(router.handle(), 21, NetConfig::default()).unwrap();
        let addr = server.addr();
        // the documented ingest wiring: drain acked updates into the
        // router flush path while the storm runs
        let consumer = std::thread::spawn(move || {
            let mut n = 0usize;
            while let Ok(ev) = rx.recv() {
                router.ingest(ev);
                n += 1;
                if n % 64 == 0 {
                    router.update_round();
                }
            }
            router.update_round();
        });

        let threads = 4usize;
        let per_thread = 1500usize;
        let q = mikrr::data::synth::ecg_like(64, 21, 52);
        let t0 = Instant::now();
        let mut joins = Vec::new();
        for t in 0..threads {
            let rows: Vec<Vec<f64>> =
                (0..64).map(|i| q.x.row((t * 16 + i) % 64).to_vec()).collect();
            joins.push(std::thread::spawn(move || {
                let mut c = NetClient::connect(addr, 1 << 20).unwrap();
                c.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
                let mut lat_us = Vec::with_capacity(per_thread);
                let mut seq = 0u64;
                for i in 0..per_thread {
                    let s = Instant::now();
                    if i % 8 == 7 {
                        // update: send and wait for the ack, resending on shed
                        loop {
                            let row = &rows[i % rows.len()];
                            let ev = StreamEvent::single(row.clone(), 1.0, t, seq);
                            seq += 1;
                            c.send_update(&ev).unwrap();
                            match c.recv().unwrap() {
                                Frame::Ack { .. } => break,
                                Frame::RetryAfter { retry_ms, .. } => std::thread::sleep(
                                    Duration::from_millis(u64::from(retry_ms.max(1))),
                                ),
                                f => panic!("unexpected frame {f:?}"),
                            }
                        }
                    } else {
                        let want = if i % 2 == 0 {
                            QueryKind::Mean
                        } else {
                            QueryKind::MeanVar
                        };
                        let req = PredictRequest::single(&rows[i % rows.len()], want);
                        loop {
                            match c.query(&req) {
                                Ok(_) => break,
                                Err(e) if e.is_transient() => {
                                    std::thread::sleep(Duration::from_millis(1))
                                }
                                Err(e) => panic!("storm predict failed: {e}"),
                            }
                        }
                    }
                    lat_us.push(s.elapsed().as_secs_f64() * 1e6);
                }
                lat_us
            }));
        }
        let mut lat: Vec<f64> = Vec::new();
        for j in joins {
            lat.extend(j.join().unwrap());
        }
        let elapsed = t0.elapsed().as_secs_f64();
        let stats = server.shutdown();
        consumer.join().unwrap();
        lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let p99 = lat[((lat.len() as f64 * 0.99) as usize).min(lat.len() - 1)];
        let rps = lat.len() as f64 / elapsed.max(1e-9);
        net_storm = Some((rps, p99));
        println!(
            "net/storm: {:.0} req/s sustained, p99 {:.0}us over {} requests \
             ({} shed, window occupancy p99 {:.1} rows)",
            rps,
            p99,
            lat.len(),
            stats.counters.get("shed_predict") + stats.counters.get("shed_update"),
            stats.window_occupancy.percentile(99.0),
        );
    }

    // ---- machine-readable reports ----
    let mut extras: Vec<(&str, f64)> =
        vec![("threads", mikrr::par::num_threads() as f64)];
    if allocs_per_round >= 0.0 {
        extras.push(("allocs_per_round_intrinsic_J253", allocs_per_round));
    }
    if let Some((rps, p99_us)) = net_storm {
        extras.push(("sustained_rps", rps));
        extras.push(("net_storm_p99_us", p99_us));
    }
    if let (Some(alloc), Some(inplace)) = (
        b.summary("incplace/incdec_alloc_J253_H6"),
        b.summary("incplace/incdec_inplace_J253_H6"),
    ) {
        let speedup = alloc.mean() / inplace.mean().max(1e-12);
        extras.push(("speedup_incdec_inplace_J253_H6", speedup));
        println!(
            "\nincplace: in-place rank-6 round {speedup:.2}x the allocating path \
             ({} -> {})",
            mikrr::util::fmt_secs(alloc.mean()),
            mikrr::util::fmt_secs(inplace.mean()),
        );
    }
    // blocked-vs-naive (same thread count) speedups for the compute core
    for (key, slow, fast) in [
        (
            "speedup_spd_factor_2024",
            "core/spd_factor_2024_naive",
            "core/spd_factor_2024_blocked",
        ),
        (
            "speedup_lu_factor_1024",
            "core/lu_factor_1024_naive",
            "core/lu_factor_1024_blocked",
        ),
        (
            "speedup_gram_sym_512_poly2",
            "core/gram_sym_general_512_poly2",
            "core/gram_sym_syrk_512_poly2",
        ),
        (
            "speedup_gram_sym_512_rbf",
            "core/gram_sym_general_512_rbf",
            "core/gram_sym_syrk_512_rbf",
        ),
        (
            "speedup_gemm_nt_packed",
            "core/gemm_nt_packed_vs_axpy/axpy_384x320_k512",
            "core/gemm_nt_packed_vs_axpy/packed_384x320_k512",
        ),
        (
            "speedup_syrk_macro_1024",
            "core/syrk_macro_1024/tiled",
            "core/syrk_macro_1024/macro",
        ),
        (
            "speedup_trsm_blocked",
            "core/trsm_blocked_vs_scalar/scalar_768",
            "core/trsm_blocked_vs_scalar/blocked_768",
        ),
        (
            "speedup_serve_microbatch",
            "serve/microbatch_predict/per_request_gemv_B64",
            "serve/microbatch_predict/microbatch_gemm_B64",
        ),
        (
            "speedup_serve_shard_k4",
            "serve/shard_round/k1_n512_plus4_minus4",
            "serve/shard_round/k4_n128_plus1_minus1",
        ),
        (
            "speedup_multi_output_predict",
            "multi/predict_d8/sequential_gemv_x8",
            "multi/predict_d8/packed_gemm",
        ),
        (
            "speedup_fold_hot_sensors",
            "multi/fold_hot_sensors/unfolded",
            "multi/fold_hot_sensors/folded",
        ),
        (
            "speedup_health_probe_vs_refit",
            "health/probe_residual/refit_J253",
            "health/probe_residual/check4_J253",
        ),
        (
            "speedup_persist_wal_vs_snapshot",
            "persist/durability/snapshot_n600",
            "persist/durability/wal_append_batch4_n600",
        ),
    ] {
        if let (Some(s), Some(f)) = (b.summary(slow), b.summary(fast)) {
            let speedup = s.mean() / f.mean().max(1e-12);
            extras.push((key, speedup));
            println!(
                "perf: {fast} {speedup:.2}x the reference ({} -> {})",
                mikrr::util::fmt_secs(s.mean()),
                mikrr::util::fmt_secs(f.mean()),
            );
        }
    }

    // telemetry overhead is a ratio gate in the opposite direction: the
    // instrumented round divided by the disabled-registry baseline, which
    // the CI perf gate holds at <= 1.03
    if let (Some(on), Some(off)) = (
        b.summary("serve/telemetry_overhead/instrumented_round_n512"),
        b.summary("serve/telemetry_overhead/disabled_round_n512"),
    ) {
        let overhead = on.mean() / off.mean().max(1e-12);
        extras.push(("overhead_telemetry_round", overhead));
        println!(
            "serve/telemetry_overhead: instrumented round {overhead:.3}x the \
             disabled baseline ({} -> {})",
            mikrr::util::fmt_secs(off.mean()),
            mikrr::util::fmt_secs(on.mean()),
        );
    }

    // ---- multi-threaded compute-core child (BENCH_microbench_mt.json) ----
    // gate on what actually ran: any active --filter is forwarded so the
    // child measures the same subset
    if b.results.iter().any(|s| s.name.starts_with("core/")) {
        match std::env::current_exe() {
            Ok(exe) => {
                let mut cmd = std::process::Command::new(exe);
                cmd.env_remove("MIKRR_THREADS")
                    .env("MIKRR_BENCH_MT_CHILD", "1");
                cmd.args(std::env::args().skip(1));
                println!("\nspawning multi-threaded compute-core child...");
                match cmd.status() {
                    Ok(s) if s.success() => {
                        if let Ok(text) =
                            std::fs::read_to_string("BENCH_microbench_mt.json")
                        {
                            for (key, name) in [
                                ("mt_speedup_spd_factor_2024", "core/spd_factor_2024_blocked"),
                                ("mt_speedup_lu_factor_1024", "core/lu_factor_1024_blocked"),
                                ("mt_speedup_gram_sym_512_rbf", "core/gram_sym_syrk_512_rbf"),
                                ("mt_speedup_gemm_512", "core/gemm_512x512x512"),
                                (
                                    "mt_speedup_gemm_nt_packed",
                                    "core/gemm_nt_packed_vs_axpy/packed_384x320_k512",
                                ),
                                ("mt_speedup_syrk_macro_1024", "core/syrk_macro_1024/macro"),
                                (
                                    "mt_speedup_trsm_blocked",
                                    "core/trsm_blocked_vs_scalar/blocked_768",
                                ),
                                (
                                    "mt_speedup_lu_panel",
                                    "core/lu_panel_packed/packed_2024x64",
                                ),
                            ] {
                                if let (Some(st), Some(mt)) = (
                                    b.summary(name).map(|s| s.mean()),
                                    bench_mean_from_json(&text, name),
                                ) {
                                    let speedup = st / mt.max(1e-12);
                                    extras.push((key, speedup));
                                    println!("core mt: {name} {speedup:.2}x single-threaded");
                                }
                            }
                            if let Some(t) = json_number_after(&text, "\"threads\": ") {
                                extras.push(("mt_threads", t));
                            }
                            // LU-panel headline: the packed panel is
                            // parallel by design (its scalar reference is
                            // serial at any thread count), so the speedup
                            // that the CI perf gate checks pairs the
                            // serial reference against the full-thread
                            // packed run from the child
                            if let (Some(st), Some(mt)) = (
                                b.summary("core/lu_panel_packed/scalar_2024x64")
                                    .map(|s| s.mean()),
                                bench_mean_from_json(
                                    &text,
                                    "core/lu_panel_packed/packed_2024x64",
                                ),
                            ) {
                                let speedup = st / mt.max(1e-12);
                                extras.push(("speedup_lu_panel_packed", speedup));
                                println!(
                                    "core: lu_panel packed (mt) {speedup:.2}x the serial \
                                     reference"
                                );
                            }
                        }
                    }
                    Ok(s) => eprintln!("(mt child exited with {s})"),
                    Err(e) => eprintln!("(could not spawn mt child: {e})"),
                }
            }
            Err(e) => eprintln!("(current_exe failed: {e})"),
        }
    }

    // no child ran (filtered out, single-core, or spawn failure): fall
    // back to the same-process ratio so the extra — and the CI perf gate
    // that reads it — is still present whenever the panel benches ran
    if !extras.iter().any(|(k, _)| *k == "speedup_lu_panel_packed") {
        if let (Some(s), Some(f)) = (
            b.summary("core/lu_panel_packed/scalar_2024x64"),
            b.summary("core/lu_panel_packed/packed_2024x64"),
        ) {
            let speedup = s.mean() / f.mean().max(1e-12);
            extras.push(("speedup_lu_panel_packed", speedup));
            println!("core: lu_panel packed (st fallback) {speedup:.2}x the serial reference");
        }
    }

    let mut inc_report = Bencher::new(mikrr::benchlib::BenchConfig::default()).quiet();
    inc_report.results = b
        .results
        .iter()
        .filter(|s| s.name.starts_with("incplace/"))
        .cloned()
        .collect();
    if let Err(e) = inc_report.write_json("BENCH_incplace.json", &extras) {
        eprintln!("(could not write BENCH_incplace.json: {e})");
    }
    if let Err(e) = b.write_json("BENCH_microbench.json", &extras) {
        eprintln!("(could not write BENCH_microbench.json: {e})");
    }

    println!("\nmicrobench done ({} benchmarks).", b.results.len());
}
