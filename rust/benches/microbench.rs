//! Micro/ablation benchmarks (beyond the paper's tables):
//!
//! * `woodbury_batch_sweep`  — rank-|H| update cost vs |H| (validates the
//!   §II.B rule: batching beats |H| rank-1 updates; fresh inverse wins
//!   only as |H| -> J).
//! * `shrink_vs_recompute`   — eq. (29) shrink vs fresh inverse as |R|
//!   grows (validates the §III.B rule).
//! * `gram_block_sweep`      — Gram construction cost vs block size.
//! * `aot_vs_native`         — the canonical woodbury update through the
//!   AOT artifact vs the native f64 path.
//! * `featmap`, `gemm`, `spd_inverse` — substrate hot spots.
//!
//! Run: cargo bench --bench microbench [-- --filter <id>] [-- --quick]

use mikrr::benchlib::{black_box, Bencher};
use mikrr::kernels::Kernel;
use mikrr::linalg::solve::spd_inverse;
use mikrr::linalg::woodbury::{bordered_shrink, incdec, sub_matrix};
use mikrr::linalg::Mat;
use mikrr::runtime::HybridExec;
use mikrr::testutil::{random_mat, random_spd};
use mikrr::util::prng::Rng;

fn main() {
    let mut b = Bencher::from_args(std::env::args().skip(1));
    let mut rng = Rng::new(1);

    // ---- woodbury batch-size sweep (J = 253, the paper's poly2 dim) ----
    let j = 253;
    let s_inv = spd_inverse(&random_spd(&mut rng, j, 60.0)).unwrap();
    for h in [1usize, 2, 4, 6, 8, 16, 32, 64] {
        let phi = random_mat(&mut rng, j, h, 0.05);
        let signs = vec![1.0; h];
        b.bench(&format!("woodbury_batch_sweep/J253_H{h}"), || {
            black_box(incdec(&s_inv, &phi, &signs).unwrap());
        });
    }
    // compare: H rank-1 updates vs one rank-H (the paper's core lever)
    {
        let h = 6;
        let phi = random_mat(&mut rng, j, h, 0.05);
        let signs = vec![1.0; h];
        b.bench("woodbury_one_rank6", || {
            black_box(incdec(&s_inv, &phi, &signs).unwrap());
        });
        b.bench("woodbury_six_rank1", || {
            let mut s = s_inv.clone();
            for k in 0..h {
                let col = Mat::from_vec(j, 1, phi.col(k)).unwrap();
                s = incdec(&s, &col, &[1.0]).unwrap();
            }
            black_box(s);
        });
        b.bench("fresh_inverse_J253", || {
            black_box(spd_inverse(&random_spd(&mut rng, j, 60.0)).unwrap());
        });
    }

    // ---- empirical shrink vs recompute (N = 400) ----
    let n = 400;
    let q = random_spd(&mut rng, n, 40.0);
    let q_inv = spd_inverse(&q).unwrap();
    for r in [2usize, 8, 32, 128, 300] {
        let rem: Vec<usize> = (0..r).map(|i| i * (n / r)).collect();
        b.bench(&format!("shrink_vs_recompute/shrink_R{r}"), || {
            black_box(bordered_shrink(&q_inv, &rem).unwrap());
        });
        let keep: Vec<usize> = (0..n).filter(|i| !rem.contains(i)).collect();
        b.bench(&format!("shrink_vs_recompute/recompute_R{r}"), || {
            let sub = sub_matrix(&q, &keep, &keep);
            black_box(spd_inverse(&sub).unwrap());
        });
    }

    // ---- gram block sweep ----
    let x = random_mat(&mut rng, 512, 21, 0.5);
    for kernel in [Kernel::poly(2, 1.0), Kernel::rbf_radius(50.0)] {
        let name = match &kernel {
            Kernel::Poly { .. } => "poly2",
            Kernel::Rbf { .. } => "rbf",
            _ => "other",
        };
        b.bench(&format!("gram_block_sweep/{name}_512x512"), || {
            black_box(kernel.gram_symmetric(&x));
        });
    }

    // ---- AOT artifact vs native (canonical shapes) ----
    {
        let ex = HybridExec::auto();
        let phi = random_mat(&mut rng, j, 6, 0.05);
        let signs = [1.0, 1.0, 1.0, 1.0, -1.0, -1.0];
        if ex.has_aot() {
            b.bench("aot_vs_native/woodbury_aot_J253_H6", || {
                black_box(ex.woodbury_incdec(&s_inv, &phi, &signs).unwrap());
            });
        } else {
            eprintln!("(aot_vs_native: artifacts not found, skipping AOT side)");
        }
        b.bench("aot_vs_native/woodbury_native_J253_H6", || {
            black_box(ex.woodbury_native(&s_inv, &phi, &signs).unwrap());
        });
    }

    // ---- full-scale sparse DRT (paper M=1e6; dense would be 6.4 GB) ----
    if b.enabled("sparse_full_scale") {
        let (xs, ys) = mikrr::data::synth::drt_like_sparse(160, 1_000_000, 0.009, 3);
        b.bench("sparse_full_scale/gram_160x160_M1e6", || {
            black_box(xs.gram(&xs, &Kernel::poly(2, 1.0)).unwrap());
        });
        let mut model =
            mikrr::krr::empirical_sparse::SparseEmpiricalKrr::fit(&xs, &ys, &Kernel::poly(2, 1.0), 0.5)
                .unwrap();
        // cycle fresh batches (+4/−4 keeps n constant and the set duplicate-
        // free: each inserted row is removed ~40 iterations later, long
        // before its batch recurs)
        let pool: Vec<_> = (0..50)
            .map(|k| mikrr::data::synth::drt_like_sparse(4, 1_000_000, 0.009, 100 + k))
            .collect();
        let mut iter = 0usize;
        b.bench("sparse_full_scale/incdec_plus4_minus4", || {
            let (xc, yc) = &pool[iter % pool.len()];
            model.inc_dec(xc, yc, &[0, 1, 2, 3]).unwrap();
            iter += 1;
        });
    }

    // ---- substrate hot spots ----
    {
        let table = Kernel::poly(2, 1.0).feature_table(21).unwrap();
        let xb = random_mat(&mut rng, 256, 21, 0.5);
        b.bench("featmap/poly2_256x21", || {
            black_box(table.map(&xb));
        });
        let a = random_mat(&mut rng, 253, 253, 1.0);
        let c = random_mat(&mut rng, 253, 253, 1.0);
        b.bench("gemm/253x253x253", || {
            black_box(mikrr::linalg::gemm::matmul(&a, &c).unwrap());
        });
        let spd = random_spd(&mut rng, 253, 30.0);
        b.bench("spd_inverse/253", || {
            black_box(spd_inverse(&spd).unwrap());
        });
    }

    println!("\nmicrobench done ({} benchmarks).", b.results.len());
}
