//! Property tests for the SIMD-packed compute core: packed GEMM, SYRK, and
//! the blocked parallel factorizations, validated against the scalar
//! references.
//!
//! This binary deliberately does NOT pin `MIKRR_THREADS`: on a multi-core
//! host the blocked kernels dispatch onto the persistent worker pool while
//! the references run serially, so every blocked-vs-naive comparison here
//! doubles as a multi-threaded-matches-single-threaded check. (Chunk
//! boundaries are deterministic and each output element is computed by
//! exactly one chunk, so parallel results are additionally expected to be
//! bitwise reproducible — asserted separately below.) To pin the inline
//! path instead, run with `MIKRR_THREADS=1`.

use mikrr::linalg::gemm::{matmul, matmul_nt_into, syrk, syrk_into};
use mikrr::linalg::solve::{
    cholesky, cholesky_naive, lu_decompose, lu_decompose_naive, spd_inverse,
};
use mikrr::linalg::Mat;
use mikrr::testutil::{assert_mat_close, random_mat, random_spd, Cases};

/// syrk_into == matmul_nt_into(A, A) on random shapes, including the
/// alpha/beta accumulate form.
#[test]
fn prop_syrk_into_matches_matmul_nt() {
    Cases::new(40, 0xB1).run(|rng| {
        let m = 1 + rng.below(90);
        let k = 1 + rng.below(60);
        let a = random_mat(rng, m, k, 0.7);
        let mut c = Mat::default();
        syrk_into(1.0, &a, 0.0, &mut c).unwrap();
        let mut want = Mat::default();
        matmul_nt_into(&a, &a, &mut want).unwrap();
        assert_mat_close(&c, &want, 1e-11);
        // exact symmetry by construction
        for i in 0..m {
            for j in 0..i {
                assert_eq!(c[(i, j)], c[(j, i)], "asymmetric at ({i},{j})");
            }
        }
        // accumulate form: 2*W - 0.5*W = 1.5*W
        let mut c2 = want.clone();
        syrk_into(-0.5, &a, 2.0, &mut c2).unwrap();
        let mut expect = want.clone();
        expect.scale(1.5);
        assert_mat_close(&c2, &expect, 1e-10);
    });
}

/// Blocked right-looking Cholesky == scalar reference to 1e-10, across the
/// unblocked/blocked crossover and multiple panel widths.
#[test]
fn prop_blocked_cholesky_matches_naive() {
    Cases::new(10, 0xB2).run(|rng| {
        let n = 60 + rng.below(200);
        let a = random_spd(rng, n, n as f64);
        let got = cholesky(&a).unwrap();
        let want = cholesky_naive(&a).unwrap();
        assert_mat_close(&got, &want, 1e-10);
        // and L L^T reconstructs A
        let rec = matmul(&got, &got.transpose()).unwrap();
        assert_mat_close(&rec, &a, 1e-9);
    });
}

/// Blocked LU == scalar reference to 1e-10: identical pivoting decisions
/// (perm and sign), matching packed factors.
#[test]
fn prop_blocked_lu_matches_naive() {
    Cases::new(10, 0xB3).run(|rng| {
        let n = 40 + rng.below(180);
        let mut a = random_mat(rng, n, n, 1.0);
        a.add_diag(3.0).unwrap();
        let got = lu_decompose(&a).unwrap();
        let want = lu_decompose_naive(&a).unwrap();
        assert_eq!(got.perm, want.perm, "n={n}: pivoting diverged");
        assert_eq!(got.sign, want.sign, "n={n}");
        assert_mat_close(&got.lu, &want.lu, 1e-10);
    });
}

/// Packed GEMM (shapes over the packed-engine thresholds) against the
/// schoolbook triple loop.
#[test]
fn packed_gemm_matches_schoolbook() {
    let mut rng = mikrr::util::prng::Rng::new(0xB4);
    for &(m, k, n) in &[(193, 140, 97), (128, 260, 64)] {
        let a = random_mat(&mut rng, m, k, 0.5);
        let b = random_mat(&mut rng, k, n, 0.5);
        let got = matmul(&a, &b).unwrap();
        let mut want = Mat::zeros(m, n);
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0;
                for kk in 0..k {
                    s += a[(i, kk)] * b[(kk, j)];
                }
                want[(i, j)] = s;
            }
        }
        assert_mat_close(&got, &want, 1e-10);
    }
}

/// Pool-dispatched kernels are bitwise reproducible: chunk boundaries are
/// deterministic and each output element is computed by exactly one chunk,
/// so which worker claims a chunk cannot change the result.
#[test]
fn parallel_kernels_are_bitwise_deterministic() {
    let mut rng = mikrr::util::prng::Rng::new(0xB5);
    let a = random_mat(&mut rng, 180, 150, 1.0);
    let b = random_mat(&mut rng, 150, 120, 1.0);
    let g1 = matmul(&a, &b).unwrap();
    let g2 = matmul(&a, &b).unwrap();
    assert!(g1 == g2, "gemm not reproducible");
    let s1 = syrk(&a).unwrap();
    let s2 = syrk(&a).unwrap();
    assert!(s1 == s2, "syrk not reproducible");
    let spd = random_spd(&mut rng, 170, 30.0);
    let l1 = cholesky(&spd).unwrap();
    let l2 = cholesky(&spd).unwrap();
    assert!(l1 == l2, "cholesky not reproducible");
    let i1 = spd_inverse(&spd).unwrap();
    let i2 = spd_inverse(&spd).unwrap();
    assert!(i1 == i2, "spd_inverse not reproducible");
}

/// The factorizations behind the engines' bootstrap agree end-to-end: a
/// fresh SPD inverse built on the blocked path matches the inverse built
/// entirely from the scalar reference factor.
#[test]
fn spd_inverse_consistent_with_naive_factor() {
    let mut rng = mikrr::util::prng::Rng::new(0xB6);
    let a = random_spd(&mut rng, 150, 25.0);
    let inv = spd_inverse(&a).unwrap();
    // reference inverse via the naive factor and per-column solves
    let l = cholesky_naive(&a).unwrap();
    let n = a.rows();
    let mut want = Mat::zeros(n, n);
    let mut col = vec![0.0; n];
    for j in 0..n {
        col.fill(0.0);
        col[j] = 1.0;
        mikrr::linalg::solve::forward_sub(&l, &mut col).unwrap();
        mikrr::linalg::solve::backward_sub_t(&l, &mut col).unwrap();
        for i in 0..n {
            want[(i, j)] = col[i];
        }
    }
    want.symmetrize();
    assert_mat_close(&inv, &want, 1e-9);
}
