//! Property tests for the SIMD-packed compute core: the shape-adaptive
//! packed dispatch (NN/NT/TN products, SYRK macro-kernel, transpose-side
//! SYRK), the blocked TRSM family, and the blocked parallel
//! factorizations, all validated against the scalar references across
//! skinny, square, and J=2024-shaped inputs.
//!
//! This binary deliberately does NOT pin `MIKRR_THREADS`: on a multi-core
//! host the blocked kernels dispatch onto the persistent worker pool while
//! the references run serially, so every blocked-vs-naive comparison here
//! doubles as a multi-threaded-matches-single-threaded check. (Chunk
//! boundaries are deterministic and each output element is computed by
//! exactly one chunk, so parallel results are additionally expected to be
//! bitwise reproducible — asserted separately below.) To pin the inline
//! path instead, run with `MIKRR_THREADS=1`.

use mikrr::linalg::gemm::{
    dispatch, gemm_tn_acc, matmul, matmul_nt, matmul_nt_dots_into, matmul_nt_into, matmul_tn,
    syrk, syrk_into, syrk_t_into, syrk_tiled_into, trsm_lower_into, trsm_lower_t_into,
    trsm_right_into,
};
use mikrr::linalg::solve::{
    backward_sub_t, cholesky, cholesky_naive, forward_sub, lu_decompose, lu_decompose_naive,
    lu_panel_factor, lu_panel_factor_scalar, spd_inverse,
};
use mikrr::linalg::Mat;
use mikrr::testutil::{assert_mat_close, random_mat, random_spd, Cases};

/// syrk_into == matmul_nt_into(A, A) on random shapes, including the
/// alpha/beta accumulate form.
#[test]
fn prop_syrk_into_matches_matmul_nt() {
    Cases::new(40, 0xB1).run(|rng| {
        let m = 1 + rng.below(90);
        let k = 1 + rng.below(60);
        let a = random_mat(rng, m, k, 0.7);
        let mut c = Mat::default();
        syrk_into(1.0, &a, 0.0, &mut c).unwrap();
        let mut want = Mat::default();
        matmul_nt_into(&a, &a, &mut want).unwrap();
        assert_mat_close(&c, &want, 1e-11);
        // exact symmetry by construction
        for i in 0..m {
            for j in 0..i {
                assert_eq!(c[(i, j)], c[(j, i)], "asymmetric at ({i},{j})");
            }
        }
        // accumulate form: 2*W - 0.5*W = 1.5*W
        let mut c2 = want.clone();
        syrk_into(-0.5, &a, 2.0, &mut c2).unwrap();
        let mut expect = want.clone();
        expect.scale(1.5);
        assert_mat_close(&c2, &expect, 1e-10);
    });
}

/// Blocked right-looking Cholesky == scalar reference to 1e-10, across the
/// unblocked/blocked crossover and multiple panel widths.
#[test]
fn prop_blocked_cholesky_matches_naive() {
    Cases::new(10, 0xB2).run(|rng| {
        let n = 60 + rng.below(200);
        let a = random_spd(rng, n, n as f64);
        let got = cholesky(&a).unwrap();
        let want = cholesky_naive(&a).unwrap();
        assert_mat_close(&got, &want, 1e-10);
        // and L L^T reconstructs A
        let rec = matmul(&got, &got.transpose()).unwrap();
        assert_mat_close(&rec, &a, 1e-9);
    });
}

/// Blocked LU == scalar reference to 1e-10: identical pivoting decisions
/// (perm and sign), matching packed factors.
#[test]
fn prop_blocked_lu_matches_naive() {
    Cases::new(10, 0xB3).run(|rng| {
        let n = 40 + rng.below(180);
        let mut a = random_mat(rng, n, n, 1.0);
        a.add_diag(3.0).unwrap();
        let got = lu_decompose(&a).unwrap();
        let want = lu_decompose_naive(&a).unwrap();
        assert_eq!(got.perm, want.perm, "n={n}: pivoting diverged");
        assert_eq!(got.sign, want.sign, "n={n}");
        assert_mat_close(&got.lu, &want.lu, 1e-10);
    });
}

/// Packed parallel LU panel == the scalar reference: identical pivot rows
/// (exact), identical sign, and **bitwise-identical** factors (the panel
/// machinery performs the same operations in the same per-element order on
/// both paths — a strictly stronger guarantee than the 1e-10 the blocked
/// sweep needs).
fn check_lu_panel(a0: &Mat, nb: usize) {
    let mut packed = a0.clone();
    let got = lu_panel_factor(&mut packed, nb).unwrap();
    let mut scalar = a0.clone();
    let want = lu_panel_factor_scalar(&mut scalar, nb).unwrap();
    assert_eq!(
        got.ipiv,
        want.ipiv,
        "({} x {}, nb={nb}) pivoting diverged",
        a0.rows(),
        a0.cols()
    );
    assert_eq!(got.sign, want.sign, "nb={nb}");
    assert_mat_close(&packed, &scalar, 1e-10);
    assert!(
        packed == scalar,
        "({} x {}, nb={nb}) packed panel not bitwise identical to scalar",
        a0.rows(),
        a0.cols()
    );
}

/// LU panel property: random tall panels across heights and widths
/// straddling every block boundary, with panels narrower than the buffer
/// (ld > nb — the mid-factorization shape).
#[test]
fn prop_lu_panel_packed_matches_scalar() {
    Cases::new(12, 0xD1).run(|rng| {
        let n = 40 + rng.below(400);
        let nb = 1 + rng.below(64);
        let cols = nb + rng.below(20);
        let a0 = random_mat(rng, n, cols, 1.0);
        check_lu_panel(&a0, nb.min(n));
    });
}

/// LU panel at the paper's J=2024 bootstrap height: a full NB=64 panel
/// over 2024 rows (the exact shape the blocked factorization hands the
/// panel machinery at the poly3 intrinsic dimension).
#[test]
fn lu_panel_packed_j2024_height() {
    let mut rng = mikrr::util::prng::Rng::new(0xD2);
    let tall = random_mat(&mut rng, 2024, 64, 0.7);
    check_lu_panel(&tall, 64);
}

/// Near-singular panels: later columns are roundoff-scale perturbations of
/// earlier ones, so post-elimination pivots decay toward 1e-9 and the
/// pivot search must resolve near-ties — bitwise equality still required
/// (both paths compare identical values in identical order).
#[test]
fn lu_panel_near_singular_resolves_ties_identically() {
    let mut rng = mikrr::util::prng::Rng::new(0xD3);
    let mut ns = random_mat(&mut rng, 500, 32, 1.0);
    for j in 16..32 {
        for i in 0..500 {
            let base = ns[(i, j - 16)];
            ns[(i, j)] = base + 1e-9 * rng.gaussian();
        }
    }
    check_lu_panel(&ns, 32);
    // tiny uniform scale: pivot magnitudes near the subnormal range
    let mut tiny = random_mat(&mut rng, 300, 24, 1.0);
    tiny.scale(1e-150);
    check_lu_panel(&tiny, 24);
}

/// Permutation-heavy panels: magnitudes grow downward so nearly every
/// column step swaps — the lazy-swap bookkeeping is exercised on every
/// column, and the recorded pivot rows must still match the reference.
#[test]
fn lu_panel_permutation_heavy() {
    let mut rng = mikrr::util::prng::Rng::new(0xD4);
    let grad = Mat::from_fn(600, 48, |r, _c| (r + 1) as f64 * (1.0 + 0.1 * rng.gaussian()));
    let mut probe = grad.clone();
    let panel = lu_panel_factor(&mut probe, 48).unwrap();
    let swaps = panel.ipiv.iter().enumerate().filter(|&(j, &p)| p != j).count();
    assert!(swaps > 24, "only {swaps}/48 columns swapped — not permutation-heavy");
    check_lu_panel(&grad, 48);
}

/// Packed GEMM (shapes over the packed-engine thresholds) against the
/// schoolbook triple loop.
#[test]
fn packed_gemm_matches_schoolbook() {
    let mut rng = mikrr::util::prng::Rng::new(0xB4);
    for &(m, k, n) in &[(193, 140, 97), (128, 260, 64)] {
        let a = random_mat(&mut rng, m, k, 0.5);
        let b = random_mat(&mut rng, k, n, 0.5);
        let got = matmul(&a, &b).unwrap();
        let mut want = Mat::zeros(m, n);
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0;
                for kk in 0..k {
                    s += a[(i, kk)] * b[(kk, j)];
                }
                want[(i, j)] = s;
            }
        }
        assert_mat_close(&got, &want, 1e-10);
    }
}

/// Pool-dispatched kernels are bitwise reproducible: chunk boundaries are
/// deterministic and each output element is computed by exactly one chunk,
/// so which worker claims a chunk cannot change the result.
#[test]
fn parallel_kernels_are_bitwise_deterministic() {
    let mut rng = mikrr::util::prng::Rng::new(0xB5);
    let a = random_mat(&mut rng, 180, 150, 1.0);
    let b = random_mat(&mut rng, 150, 120, 1.0);
    let g1 = matmul(&a, &b).unwrap();
    let g2 = matmul(&a, &b).unwrap();
    assert!(g1 == g2, "gemm not reproducible");
    let s1 = syrk(&a).unwrap();
    let s2 = syrk(&a).unwrap();
    assert!(s1 == s2, "syrk not reproducible");
    let spd = random_spd(&mut rng, 170, 30.0);
    let l1 = cholesky(&spd).unwrap();
    let l2 = cholesky(&spd).unwrap();
    assert!(l1 == l2, "cholesky not reproducible");
    let i1 = spd_inverse(&spd).unwrap();
    let i2 = spd_inverse(&spd).unwrap();
    assert!(i1 == i2, "spd_inverse not reproducible");
}

/// Packed NT products (`A B^T`) match the row-dot reference to 1e-10
/// across random shapes straddling the dispatch crossover, plus fixed
/// skinny / square / J=2024-shaped cases pinned to the packed engine.
#[test]
fn prop_packed_nt_matches_rowdots() {
    Cases::new(20, 0xC1).run(|rng| {
        let m = 1 + rng.below(160);
        let n = 1 + rng.below(160);
        let k = 1 + rng.below(280);
        let a = random_mat(rng, m, k, 0.6);
        let b = random_mat(rng, n, k, 0.6);
        let got = matmul_nt(&a, &b).unwrap();
        let mut want = Mat::default();
        matmul_nt_dots_into(&a, &b, &mut want).unwrap();
        assert_mat_close(&got, &want, 1e-10);
    });
    // pinned to the packed engine: skinny (tall × narrow, the J=2024
    // update-algebra shape), square, and wide
    let mut rng = mikrr::util::prng::Rng::new(0xC2);
    for &(m, k, n) in &[(2024, 40, 48), (160, 160, 160), (48, 300, 200)] {
        assert!(dispatch::use_packed(m, n, k), "({m},{k},{n}) must be packed");
        let a = random_mat(&mut rng, m, k, 0.5);
        let b = random_mat(&mut rng, n, k, 0.5);
        let got = matmul_nt(&a, &b).unwrap();
        let mut want = Mat::default();
        matmul_nt_dots_into(&a, &b, &mut want).unwrap();
        assert_mat_close(&got, &want, 1e-10);
    }
}

/// Packed TN products (`A^T B` accumulate) match the explicit-transpose
/// reference to 1e-10 on both sides of the crossover.
#[test]
fn prop_packed_tn_matches_reference() {
    Cases::new(20, 0xC3).run(|rng| {
        let k = 1 + rng.below(280);
        let m = 1 + rng.below(140);
        let n = 1 + rng.below(140);
        let a = random_mat(rng, k, m, 0.6);
        let b = random_mat(rng, k, n, 0.6);
        let mut c = random_mat(rng, m, n, 0.3);
        let mut want = matmul(&a.transpose(), &b).unwrap();
        want.scale(1.5);
        want.axpy(1.0, &c).unwrap();
        gemm_tn_acc(1.5, &a, &b, &mut c).unwrap();
        assert_mat_close(&c, &want, 1e-10);
        // the allocating wrapper takes the same dispatch
        let tn = matmul_tn(&a, &b).unwrap();
        assert_mat_close(&tn, &matmul(&a.transpose(), &b).unwrap(), 1e-10);
    });
}

/// The SYRK macro-kernel (packed lower-only path) matches the 4×4
/// dot-tile reference to 1e-10, including a J=2024-shaped Gram build, and
/// stays exactly symmetric.
#[test]
fn prop_syrk_macro_matches_tiled() {
    Cases::new(15, 0xC4).run(|rng| {
        let m = 1 + rng.below(200);
        let k = 1 + rng.below(220);
        let a = random_mat(rng, m, k, 0.6);
        let mut got = Mat::default();
        syrk_into(1.0, &a, 0.0, &mut got).unwrap();
        let mut want = Mat::default();
        syrk_tiled_into(1.0, &a, 0.0, &mut want).unwrap();
        assert_mat_close(&got, &want, 1e-10);
        for i in 0..m {
            for j in 0..i {
                assert_eq!(got[(i, j)], got[(j, i)], "asymmetric at ({i},{j})");
            }
        }
    });
    // the paper's poly3 intrinsic dimension: a (2024, 40) panel product
    // through the macro-kernel
    let mut rng = mikrr::util::prng::Rng::new(0xC5);
    let a = random_mat(&mut rng, 2024, 40, 0.4);
    assert!(dispatch::use_packed(a.rows(), a.rows(), a.cols()));
    let mut got = Mat::default();
    syrk_into(1.0, &a, 0.0, &mut got).unwrap();
    let mut want = Mat::default();
    syrk_tiled_into(1.0, &a, 0.0, &mut want).unwrap();
    assert_mat_close(&got, &want, 1e-10);
}

/// The transpose-side SYRK (`A^T A`, the scatter/precision build) matches
/// the explicit-transpose reference on both sides of the crossover.
#[test]
fn prop_syrk_t_matches_reference() {
    Cases::new(15, 0xC6).run(|rng| {
        let k = 1 + rng.below(220);
        let m = 1 + rng.below(160);
        let a = random_mat(rng, k, m, 0.6);
        let mut got = Mat::default();
        syrk_t_into(1.0, &a, 0.0, &mut got).unwrap();
        let want = syrk(&a.transpose()).unwrap();
        assert_mat_close(&got, &want, 1e-10);
    });
}

/// Blocked TRSM (forward, backward, and right-side) matches per-column /
/// per-row scalar substitution to 1e-10 across sizes straddling the block
/// width, including RHS widths that push the trailing update onto the
/// packed engine.
#[test]
fn prop_trsm_matches_substitution() {
    Cases::new(10, 0xC7).run(|rng| {
        let n = 2 + rng.below(260);
        let nrhs = 1 + rng.below(200);
        let spd = random_spd(rng, n, n as f64);
        let l = cholesky(&spd).unwrap();
        let b0 = random_mat(rng, n, nrhs, 0.8);
        let mut col = vec![0.0; n];
        // forward: L X = B
        let mut x = b0.clone();
        trsm_lower_into(&l, false, &mut x).unwrap();
        let mut want = Mat::zeros(n, nrhs);
        for j in 0..nrhs {
            for (i, c) in col.iter_mut().enumerate() {
                *c = b0[(i, j)];
            }
            forward_sub(&l, &mut col).unwrap();
            for (i, c) in col.iter().enumerate() {
                want[(i, j)] = *c;
            }
        }
        assert_mat_close(&x, &want, 1e-10);
        // backward: L^T X = B
        let mut xt = b0.clone();
        trsm_lower_t_into(&l, false, &mut xt).unwrap();
        let mut want_t = Mat::zeros(n, nrhs);
        for j in 0..nrhs {
            for (i, c) in col.iter_mut().enumerate() {
                *c = b0[(i, j)];
            }
            backward_sub_t(&l, &mut col).unwrap();
            for (i, c) in col.iter().enumerate() {
                want_t[(i, j)] = *c;
            }
        }
        assert_mat_close(&xt, &want_t, 1e-10);
        // right-side: X L^T = B, checked by residual
        let rows = 1 + rng.below(120);
        let br = random_mat(rng, rows, n, 0.8);
        let mut xr = br.clone();
        trsm_right_into(&mut xr, &l, false).unwrap();
        let rec = matmul_nt(&xr, &l).unwrap();
        assert_mat_close(&rec, &br, 1e-9);
    });
}

/// The factorizations behind the engines' bootstrap agree end-to-end: a
/// fresh SPD inverse built on the blocked path matches the inverse built
/// entirely from the scalar reference factor.
#[test]
fn spd_inverse_consistent_with_naive_factor() {
    let mut rng = mikrr::util::prng::Rng::new(0xB6);
    let a = random_spd(&mut rng, 150, 25.0);
    let inv = spd_inverse(&a).unwrap();
    // reference inverse via the naive factor and per-column solves
    let l = cholesky_naive(&a).unwrap();
    let n = a.rows();
    let mut want = Mat::zeros(n, n);
    let mut col = vec![0.0; n];
    for j in 0..n {
        col.fill(0.0);
        col[j] = 1.0;
        mikrr::linalg::solve::forward_sub(&l, &mut col).unwrap();
        mikrr::linalg::solve::backward_sub_t(&l, &mut col).unwrap();
        for i in 0..n {
            want[(i, j)] = col[i];
        }
    }
    want.symmetrize();
    assert_mat_close(&inv, &want, 1e-9);
}
