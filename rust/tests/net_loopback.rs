//! Loopback acceptance tests for the network serving front-end (ISSUE 9):
//!
//! * **Bitwise parity** — concurrent socket predicts decode to answers
//!   bitwise-identical to direct [`RouterHandle::query`] calls (the wire
//!   codec carries IEEE-754 bit patterns and the reactor batches through
//!   the same `QueryLanes` the in-process server uses).
//! * **Exact shedding** — an over-budget predict burst yields exactly
//!   `M - budget` `RetryAfter` frames; an over-queue update burst yields
//!   exactly `M - queue` sheds; pending rows never exceed the budget.
//! * **Socket-boundary rejection** — torn frames, oversize lengths, and
//!   every-byte bit flips never produce a valid response and never kill
//!   the server.

use std::time::Duration;

use mikrr::data::synth;
use mikrr::error::Error;
use mikrr::kernels::Kernel;
use mikrr::linalg::Mat;
use mikrr::net::frame::{encode_predict, peek_frame, Frame};
use mikrr::net::{NetClient, NetConfig, NetServer};
use mikrr::serve::router::{RouterHandle, ServeConfig, ShardRouter};
use mikrr::serve::{MicroBatchPolicy, PredictRequest, PredictResponse, QueryKind};
use mikrr::streaming::StreamEvent;
use mikrr::telemetry::{HistId, MetricId, SpanKind};

const DIM: usize = 5;

fn router(uncertainty: bool) -> ShardRouter {
    let d = synth::ecg_like(60, DIM, 1);
    let mut cfg = ServeConfig::default_for(Kernel::poly(2, 1.0), 2);
    cfg.base.with_uncertainty = uncertainty;
    ShardRouter::bootstrap(&d.x, &d.y, cfg).unwrap()
}

fn direct(h: &RouterHandle, x: &Mat, want: QueryKind) -> PredictResponse {
    h.query(&PredictRequest::new(x.clone(), want)).unwrap()
}

fn assert_bitwise(got: &PredictResponse, want: &PredictResponse) {
    assert_eq!(got.mean.shape(), want.mean.shape());
    for (g, w) in got.mean.as_slice().iter().zip(want.mean.as_slice()) {
        assert_eq!(g.to_bits(), w.to_bits(), "mean bits differ: {g} vs {w}");
    }
    match (&got.variance, &want.variance) {
        (None, None) => {}
        (Some(g), Some(w)) => {
            assert_eq!(g.len(), w.len());
            for (a, b) in g.iter().zip(w) {
                assert_eq!(a.to_bits(), b.to_bits(), "variance bits differ: {a} vs {b}");
            }
        }
        (g, w) => panic!("variance presence differs: {g:?} vs {w:?}"),
    }
}

#[test]
fn concurrent_socket_predicts_are_bitwise_identical_to_direct_query() {
    let r = router(true);
    let h = r.handle();
    let (server, _rx) = NetServer::spawn(h.clone(), DIM, NetConfig::default()).unwrap();
    let addr = server.addr();
    let q = synth::ecg_like(8, DIM, 2);
    let dmean = direct(&h, &q.x, QueryKind::Mean);
    let dvar = direct(&h, &q.x, QueryKind::MeanVar);

    // 4 client threads, each querying its own rows for both kinds: the
    // reactor coalesces them into shared windows in arrival order, and
    // every per-row answer must still be bit-identical to a direct call
    let mut joins = Vec::new();
    for t in 0..4usize {
        let rows: Vec<Vec<f64>> = (0..2).map(|i| q.x.row(t * 2 + i).to_vec()).collect();
        joins.push(std::thread::spawn(move || {
            let mut c = NetClient::connect(addr, 1 << 20).unwrap();
            c.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
            rows.iter()
                .map(|row| {
                    let m = c
                        .query(&PredictRequest::single(row, QueryKind::Mean))
                        .unwrap();
                    let v = c
                        .query(&PredictRequest::single(row, QueryKind::MeanVar))
                        .unwrap();
                    (m, v)
                })
                .collect::<Vec<_>>()
        }));
    }
    for (t, j) in joins.into_iter().enumerate() {
        for (i, (m, v)) in j.join().unwrap().into_iter().enumerate() {
            let row = t * 2 + i;
            assert_eq!(m.mean.shape(), (1, 1));
            assert_eq!(
                m.mean[(0, 0)].to_bits(),
                dmean.mean[(row, 0)].to_bits(),
                "row {row} mean differs from direct query"
            );
            assert_eq!(
                v.mean[(0, 0)].to_bits(),
                dvar.mean[(row, 0)].to_bits(),
                "row {row} posterior mean differs"
            );
            assert_eq!(
                v.variance_at(0).to_bits(),
                dvar.variance_at(row).to_bits(),
                "row {row} variance differs"
            );
        }
    }
    let stats = server.shutdown();
    assert_eq!(stats.counters.get("predicts_served"), 16);
    assert_eq!(stats.counters.get("shed_predict"), 0);
    assert_eq!(stats.counters.get("protocol_errors"), 0);
}

#[test]
fn multi_row_and_multi_output_requests_round_trip_bitwise() {
    let d = synth::ecg_like(60, DIM, 1);
    let y = Mat::from_fn(60, 2, |i, j| if j == 0 { d.y[i] } else { 2.0 * d.y[i] - 0.5 });
    let mut cfg = ServeConfig::default_for(Kernel::poly(2, 1.0), 2);
    cfg.base.with_uncertainty = true;
    let r = ShardRouter::bootstrap_multi(&d.x, &y, cfg).unwrap();
    let h = r.handle();
    let (server, _rx) = NetServer::spawn(h.clone(), DIM, NetConfig::default()).unwrap();
    let q = synth::ecg_like(6, DIM, 3);

    let mut c = NetClient::connect(server.addr(), 1 << 20).unwrap();
    c.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    for want in [QueryKind::MeanMulti, QueryKind::MeanVarMulti] {
        let got = c.query(&PredictRequest::new(q.x.clone(), want)).unwrap();
        assert_eq!(got.mean.shape(), (6, 2));
        assert_bitwise(&got, &direct(&h, &q.x, want));
    }
    drop(c);
    server.shutdown();
}

#[test]
fn over_budget_predict_storm_sheds_exactly_the_excess() {
    let r = router(false);
    let h = r.handle();
    let budget = 5usize;
    let m = 12usize;
    let cfg = NetConfig {
        // window larger than the budget so admission alone decides; a
        // long max_wait keeps the window open until every frame landed
        batch: MicroBatchPolicy { max_rows: 64, max_wait: Duration::from_millis(300) },
        pending_budget: budget,
        max_inflight_per_conn: m + 1,
        ..NetConfig::default()
    };
    let (server, _rx) = NetServer::spawn(h, DIM, cfg).unwrap();
    let q = synth::ecg_like(m, DIM, 4);

    let mut c = NetClient::connect(server.addr(), 1 << 20).unwrap();
    c.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    // pipeline all M single-row predicts, then collect all M answers
    let mut ids = Vec::new();
    for i in 0..m {
        ids.push(
            c.send_predict(&PredictRequest::single(q.x.row(i), QueryKind::Mean))
                .unwrap(),
        );
    }
    let mut responses = 0usize;
    let mut sheds = 0usize;
    for _ in 0..m {
        match c.recv().unwrap() {
            Frame::Response { id, .. } => {
                assert!(ids.contains(&id));
                responses += 1;
            }
            Frame::RetryAfter { id, retry_ms } => {
                assert!(ids.contains(&id));
                assert!(retry_ms > 0);
                sheds += 1;
            }
            f => panic!("unexpected frame {f:?}"),
        }
    }
    assert_eq!(responses, budget, "every admitted row is answered");
    assert_eq!(sheds, m - budget, "every over-budget row is shed, exactly once");

    let stats = server.shutdown();
    assert_eq!(stats.counters.get("shed_predict") as usize, m - budget);
    assert_eq!(stats.counters.get("predicts_served") as usize, budget);
    assert!(
        stats.max_pending_rows <= budget,
        "admitted rows ({}) exceeded the pending budget ({budget})",
        stats.max_pending_rows
    );
    assert!(stats.window_occupancy.percentile(99.0) <= budget as f64);
}

#[test]
fn over_queue_update_storm_sheds_exactly_the_excess() {
    let r = router(false);
    let queue = 4usize;
    let m = 10usize;
    let cfg = NetConfig { update_queue: queue, ..NetConfig::default() };
    // hold the receiver WITHOUT draining: the bounded queue must shed,
    // never grow
    let (server, rx) = NetServer::spawn(r.handle(), DIM, cfg).unwrap();

    let mut c = NetClient::connect(server.addr(), 1 << 20).unwrap();
    c.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    for i in 0..m {
        let ev = StreamEvent::single(vec![0.1 * i as f64; DIM], 1.0, 0, i as u64);
        c.send_update(&ev).unwrap();
    }
    let (mut acks, mut sheds) = (0usize, 0usize);
    for _ in 0..m {
        match c.recv().unwrap() {
            Frame::Ack { .. } => acks += 1,
            Frame::RetryAfter { .. } => sheds += 1,
            f => panic!("unexpected frame {f:?}"),
        }
    }
    assert_eq!(acks, queue);
    assert_eq!(sheds, m - queue);
    // exactly the admitted events sit in the queue, in order
    let admitted: Vec<StreamEvent> = rx.try_iter().collect();
    assert_eq!(admitted.len(), queue);
    for (i, ev) in admitted.iter().enumerate() {
        assert_eq!(ev.seq, i as u64);
    }
    let stats = server.shutdown();
    assert_eq!(stats.counters.get("updates_admitted") as usize, queue);
    assert_eq!(stats.counters.get("shed_update") as usize, m - queue);
}

#[test]
fn acked_updates_flow_into_the_router_ingest_path() {
    let mut r = router(false);
    let before = r.n_samples();
    let (server, rx) = NetServer::spawn(r.handle(), DIM, NetConfig::default()).unwrap();

    // the documented wiring: drain the receiver into ingest + update_round
    let consumer = std::thread::spawn(move || {
        let mut got = 0usize;
        while let Ok(ev) = rx.recv() {
            r.ingest(ev);
            got += 1;
        }
        let report = r.update_round();
        (r, got, report)
    });

    let n = 6usize;
    let d = synth::ecg_like(n, DIM, 5);
    let mut c = NetClient::connect(server.addr(), 1 << 20).unwrap();
    c.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    for i in 0..n {
        c.send_update(&StreamEvent::single(d.x.row(i).to_vec(), d.y[i], 0, i as u64))
            .unwrap();
    }
    for _ in 0..n {
        assert!(matches!(c.recv().unwrap(), Frame::Ack { .. }));
    }
    // shutting down drops the reactor's sender, ending the consumer loop
    let stats = server.shutdown();
    assert_eq!(stats.counters.get("updates_admitted") as usize, n);
    let (r, got, report) = consumer.join().unwrap();
    assert_eq!(got, n, "every acked event reached the consumer");
    assert!(report.added() >= 1, "the flush applied the acked events");
    assert!(r.n_samples() > before);
}

#[test]
fn wrong_dim_and_zero_row_requests_error_cleanly() {
    let r = router(false);
    let (server, _rx) = NetServer::spawn(r.handle(), DIM, NetConfig::default()).unwrap();
    let mut c = NetClient::connect(server.addr(), 1 << 20).unwrap();
    c.set_read_timeout(Some(Duration::from_secs(10))).unwrap();

    let e = c
        .query(&PredictRequest::single(&[1.0, 2.0], QueryKind::Mean))
        .unwrap_err();
    assert!(matches!(e, Error::Config(_)), "shape errors are permanent: {e:?}");

    let empty = PredictRequest::new(Mat::zeros(0, DIM), QueryKind::Mean);
    assert!(c.query(&empty).is_err());

    // the connection survives request-level errors
    let q = synth::ecg_like(1, DIM, 6);
    assert!(c.query(&PredictRequest::single(q.x.row(0), QueryKind::Mean)).is_ok());
    server.shutdown();
}

#[test]
fn corrupt_and_oversize_frames_close_the_connection_not_the_server() {
    let r = router(false);
    let h = r.handle();
    let cfg = NetConfig { max_frame_len: 4096, ..NetConfig::default() };
    let (server, _rx) = NetServer::spawn(h, DIM, cfg).unwrap();
    let addr = server.addr();
    let q = synth::ecg_like(1, DIM, 7);
    let req = PredictRequest::single(q.x.row(0), QueryKind::Mean);

    // CRC corruption: server answers a permanent error and closes
    let mut wire = Vec::new();
    encode_predict(&mut wire, &mut Vec::new(), 1, &req);
    let mut bad = wire.clone();
    let last = bad.len() - 1;
    bad[last] ^= 0x01; // corrupt the CRC itself
    let mut c = NetClient::connect(addr, 1 << 20).unwrap();
    c.set_read_timeout(Some(Duration::from_millis(500))).unwrap();
    c.send_raw(&bad).unwrap();
    match c.recv() {
        Ok(Frame::Error { transient, .. }) => assert!(!transient),
        Ok(f) => panic!("corrupt frame produced {f:?}"),
        Err(_) => {} // already closed: equally acceptable
    }
    assert!(c.recv().is_err(), "connection stays closed after a torn frame");

    // oversize declared length: rejected from the header alone
    let mut c = NetClient::connect(addr, 1 << 20).unwrap();
    c.set_read_timeout(Some(Duration::from_millis(500))).unwrap();
    let mut oversize = Vec::new();
    oversize.extend_from_slice(&mikrr::net::frame::TAG_PREDICT.to_le_bytes());
    oversize.extend_from_slice(&(1u64 << 40).to_le_bytes());
    c.send_raw(&oversize).unwrap();
    match c.recv() {
        Ok(Frame::Error { transient, .. }) => assert!(!transient),
        Ok(f) => panic!("oversize header produced {f:?}"),
        Err(_) => {}
    }

    // a torn frame (valid prefix, missing tail) just waits server-side;
    // dropping the connection mid-frame must not wedge the reactor
    let mut c = NetClient::connect(addr, 1 << 20).unwrap();
    c.send_raw(&wire[..wire.len() / 2]).unwrap();
    drop(c);

    // the server is still fully alive for new connections
    let mut c = NetClient::connect(addr, 1 << 20).unwrap();
    c.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let got = c.query(&req).unwrap();
    assert_eq!(got.mean.shape(), (1, 1));
    let stats = server.shutdown();
    assert!(stats.counters.get("protocol_errors") >= 2);
}

#[test]
fn stats_pull_sees_live_traffic_and_is_bitwise_stable_when_idle() {
    let r = router(false);
    let (server, _rx) = NetServer::spawn(r.handle(), DIM, NetConfig::default()).unwrap();
    let q = synth::ecg_like(4, DIM, 9);

    let mut c = NetClient::connect(server.addr(), 1 << 20).unwrap();
    c.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    for i in 0..4 {
        c.query(&PredictRequest::single(q.x.row(i), QueryKind::Mean))
            .unwrap();
    }

    // the merged fleet view shows reactor-side and shard-side activity
    let snap = c.stats().unwrap();
    assert_eq!(snap.counter(MetricId::PredictsServed), 4);
    assert_eq!(snap.counter(MetricId::Accepted), 1);
    assert_eq!(snap.counter(MetricId::ProtocolErrors), 0);
    assert!(snap.counter(MetricId::Batches) >= 1);
    assert!(
        snap.hist(HistId::WindowOccupancyRows).count >= 1,
        "window occupancy histogram populated by live traffic"
    );
    assert!(
        snap.spans.iter().any(|e| e.kind == SpanKind::Accept),
        "flight-recorder tail carries the accept span"
    );
    assert!(
        snap.spans.iter().any(|e| e.kind == SpanKind::WindowExec),
        "flight-recorder tail carries window executions"
    );

    // the pull path records nothing: two idle pulls decode equal, and
    // the canonical encoding makes the payloads byte-identical too
    let again = c.stats().unwrap();
    assert_eq!(snap, again, "idle stats pulls must be bitwise-stable");
    let (mut a, mut b) = (Vec::new(), Vec::new());
    snap.encode(&mut a);
    again.encode(&mut b);
    assert_eq!(a, b, "canonical snapshot encoding is unique");

    // renderers work on a live snapshot (smoke: non-empty, named slots)
    let text = snap.render_text();
    assert!(text.contains("predicts_served"), "{text}");
    let mut json = String::new();
    snap.write_json(&mut json);
    assert!(json.contains("\"predicts_served\""), "{json}");
    server.shutdown();
}

#[test]
fn every_byte_flip_at_the_socket_never_yields_a_valid_response() {
    let r = router(false);
    let h = r.handle();
    let cfg = NetConfig { max_frame_len: 4096, ..NetConfig::default() };
    let (server, _rx) = NetServer::spawn(h.clone(), DIM, cfg).unwrap();
    let addr = server.addr();
    let q = synth::ecg_like(1, DIM, 8);
    let req = PredictRequest::single(q.x.row(0), QueryKind::Mean);
    let mut wire = Vec::new();
    encode_predict(&mut wire, &mut Vec::new(), 9, &req);
    assert_eq!(peek_frame(&wire, 4096).unwrap(), Some(wire.len()));

    for i in 0..wire.len() {
        let mut bad = wire.clone();
        bad[i] ^= 0x01;
        let mut c = NetClient::connect(addr, 4096 + 64).unwrap();
        // short timeout: a flip that inflates the length makes the server
        // wait for bytes that never come — a safe outcome, scored as such
        c.set_read_timeout(Some(Duration::from_millis(250))).unwrap();
        c.send_raw(&bad).unwrap();
        match c.recv() {
            Ok(Frame::Error { .. }) => {}  // rejected loudly
            Err(_) => {}                   // closed or timed out: safe
            Ok(f) => panic!("flip at byte {i} produced a non-error frame {f:?}"),
        }
    }
    // after the whole gauntlet the server still answers correctly
    let mut c = NetClient::connect(addr, 1 << 20).unwrap();
    c.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let got = c.query(&req).unwrap();
    assert_bitwise(&got, &direct(&h, &req.x, QueryKind::Mean));
    server.shutdown();
}
