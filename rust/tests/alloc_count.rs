//! The zero-allocation contract, measured: steady-state `inc_dec` on every
//! maintained-inverse engine must not touch the heap — including folded
//! (duplicate-input) rounds and multi-output (`D > 1`) rounds/reads.
//!
//! A counting global allocator diffs allocation events around warmed-up
//! update rounds. `MIKRR_THREADS=1` pins the single-threaded path (scoped
//! thread spawns allocate; the contract is defined for the inline path —
//! see `par::num_threads`'s caching note). Everything lives in ONE `#[test]`
//! so no sibling test thread allocates concurrently during the measured
//! sections.

use mikrr::kbr::{KbrHyper, KbrModel, KbrPredictWork};
use mikrr::kernels::Kernel;
use mikrr::krr::empirical::{EmpiricalKrr, EmpiricalPredictWork};
use mikrr::krr::intrinsic::{IntrinsicKrr, IntrinsicPredictWork};
use mikrr::krr::KrrModel;
use mikrr::linalg::matrix::dot;
use mikrr::linalg::Mat;
use mikrr::util::alloc_counter::{self, CountingAlloc};
use mikrr::util::prng::Rng;

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

fn data(n: usize, m: usize, seed: u64) -> (Mat, Vec<f64>) {
    let mut rng = Rng::new(seed);
    let w: Vec<f64> = rng.gaussian_vec(m);
    let x = Mat::from_fn(n, m, |_, _| 0.5 * rng.gaussian());
    let y: Vec<f64> = (0..n)
        .map(|i| dot(x.row(i), &w) + 0.05 * rng.gaussian())
        .collect();
    (x, y)
}

/// Warm `round` up, then measure allocation events across `measured` more
/// executions and return the total.
fn steady_state_allocs(mut round: impl FnMut(), warmup: usize, measured: usize) -> u64 {
    for _ in 0..warmup {
        round();
    }
    let before = alloc_counter::count();
    for _ in 0..measured {
        round();
    }
    alloc_counter::count() - before
}

#[test]
fn steady_state_inc_dec_is_allocation_free() {
    // must run before ANY parallel code path: num_threads() caches on first
    // use, and thread spawns would otherwise count as allocations
    #[allow(unused_unsafe)]
    unsafe {
        std::env::set_var("MIKRR_THREADS", "1")
    };

    let rounds = 8usize;
    let batch = 4usize;
    // pre-build a pool of insertion batches so the rounds themselves only
    // read; +4/−4 (removing the oldest rows) keeps N constant, which is the
    // steady state the contract is about
    let pool: Vec<(Mat, Vec<f64>)> = (0..12).map(|k| data(batch, 4, 100 + k)).collect();
    let rem: Vec<usize> = (0..batch).collect();

    // --- IntrinsicKrr (poly2, J = 15) ---
    {
        let (x, y) = data(40, 4, 1);
        let mut model = IntrinsicKrr::fit(&x, &y, &Kernel::poly(2, 1.0), 0.5).unwrap();
        let mut k = 0usize;
        let allocs = steady_state_allocs(
            || {
                let (xc, yc) = &pool[k % pool.len()];
                k += 1;
                model.inc_dec(xc, yc, &rem).unwrap();
            },
            4,
            rounds,
        );
        assert_eq!(
            allocs, 0,
            "IntrinsicKrr steady-state inc_dec allocated {allocs} times \
             over {rounds} rounds"
        );
        assert_eq!(model.n_samples(), 40);
    }

    // --- EmpiricalKrr, poly kernel ---
    {
        let (x, y) = data(40, 4, 2);
        let mut model = EmpiricalKrr::fit(&x, &y, &Kernel::poly(2, 1.0), 0.5).unwrap();
        let mut k = 0usize;
        let allocs = steady_state_allocs(
            || {
                let (xc, yc) = &pool[k % pool.len()];
                k += 1;
                model.inc_dec(xc, yc, &rem).unwrap();
            },
            4,
            rounds,
        );
        assert_eq!(
            allocs, 0,
            "EmpiricalKrr (poly) steady-state inc_dec allocated {allocs} times"
        );
    }

    // --- EmpiricalKrr, RBF kernel (exercises the Gram norm scratch) ---
    {
        let (x, y) = data(40, 4, 3);
        let mut model = EmpiricalKrr::fit(&x, &y, &Kernel::rbf_radius(2.0), 0.5).unwrap();
        let mut k = 0usize;
        let allocs = steady_state_allocs(
            || {
                let (xc, yc) = &pool[k % pool.len()];
                k += 1;
                model.inc_dec(xc, yc, &rem).unwrap();
            },
            4,
            rounds,
        );
        assert_eq!(
            allocs, 0,
            "EmpiricalKrr (rbf) steady-state inc_dec allocated {allocs} times"
        );
    }

    // --- KbrModel (posterior update) ---
    {
        let (x, y) = data(30, 4, 4);
        let mut model =
            KbrModel::fit(&x, &y, &Kernel::poly(2, 1.0), KbrHyper::default()).unwrap();
        let mut k = 0usize;
        let allocs = steady_state_allocs(
            || {
                let (xc, yc) = &pool[k % pool.len()];
                k += 1;
                model.inc_dec(xc, yc, &rem).unwrap();
            },
            4,
            rounds,
        );
        assert_eq!(
            allocs, 0,
            "KbrModel steady-state inc_dec allocated {allocs} times"
        );
        assert_eq!(model.n_samples(), 30);
    }

    // --- duplicate-input folding (engine-level, KRR + KBR twin): a warm
    // folding round — plan, fresh-row gather, rank-1 fold updates, and the
    // multiplicity/ȳ mirrors — must stay off the heap too. Batches where
    // rows 2/3 exactly repeat rows 0/1 plan 2 fresh + 2 within-batch folds
    // every round regardless of store contents; evicting [0, 1] keeps N
    // constant ---
    {
        use mikrr::config::Space;
        use mikrr::coordinator::engine::Engine;

        let (x, y) = data(40, 4, 9);
        let mut eng =
            Engine::fit(&x, &y, &Kernel::poly(2, 1.0), 0.5, Space::Intrinsic, true).unwrap();
        eng.set_fold_eps(Some(0.0));
        // 12 distinct batches: warmup 4 + measured 8 rounds never reuse
        // one, so a batch's rows can't exact-match a stored copy of itself
        let fold_pool: Vec<(Mat, Vec<f64>)> = (0..12)
            .map(|k| {
                let (xb, yb) = data(2, 4, 200 + k);
                let xf = Mat::from_fn(4, 4, |r, c| xb[(r % 2, c)]);
                let yf = vec![yb[0], yb[1], yb[0] + 0.1, yb[1] - 0.1];
                (xf, yf)
            })
            .collect();
        let rem2 = [0usize, 1];
        let mut k = 0usize;
        let allocs = steady_state_allocs(
            || {
                let (xc, yc) = &fold_pool[k % fold_pool.len()];
                k += 1;
                eng.inc_dec(xc, yc, &rem2).unwrap();
                assert_eq!(eng.last_round_folds(), 2);
            },
            4,
            rounds,
        );
        assert_eq!(
            allocs, 0,
            "warm folding inc_dec (KRR + KBR twin) allocated {allocs} times"
        );
        assert_eq!(eng.n_samples(), 40);
    }

    // --- multi-output target path (D = 3): warm inc_dec_multi through one
    // maintained inverse with D coefficient columns, then the packed
    // (B, D) predict_multi_into / shared-variance uncertainty reads ---
    {
        use mikrr::config::Space;
        use mikrr::coordinator::engine::{Engine, EnginePredictWork};

        let (x, y) = data(40, 4, 20);
        let dcols = 3usize;
        let ym = Mat::from_fn(40, dcols, |i, c| (1.0 + 0.5 * c as f64) * y[i]);
        let mut eng =
            Engine::fit_multi(&x, &ym, &Kernel::poly(2, 1.0), 0.5, Space::Intrinsic, true)
                .unwrap();

        let mpool: Vec<(Mat, Mat)> = (0..12)
            .map(|k| {
                let (xb, yb) = data(batch, 4, 300 + k);
                let yms = Mat::from_fn(batch, dcols, |i, c| (1.0 + 0.5 * c as f64) * yb[i]);
                (xb, yms)
            })
            .collect();
        let mut k = 0usize;
        let allocs = steady_state_allocs(
            || {
                let (xc, yc) = &mpool[k % mpool.len()];
                k += 1;
                eng.inc_dec_multi(xc, yc, &rem).unwrap();
            },
            4,
            rounds,
        );
        assert_eq!(
            allocs, 0,
            "warm multi-output inc_dec_multi (D = 3) allocated {allocs} times"
        );
        assert_eq!(eng.n_samples(), 40);
        assert_eq!(eng.n_outputs(), dcols);

        let (xq, _) = data(16, 4, 21);
        let mut w = EnginePredictWork::default();
        let mut out = Mat::default();
        let mut mean = Mat::default();
        let mut var = Vec::new();
        eng.predict_multi_into(&xq, &mut out, &mut w).unwrap(); // warm
        eng.predict_with_uncertainty_multi_into(&xq, &mut mean, &mut var, &mut w)
            .unwrap(); // warm
        let allocs = steady_state_allocs(
            || {
                eng.predict_multi_into(&xq, &mut out, &mut w).unwrap();
                eng.predict_with_uncertainty_multi_into(&xq, &mut mean, &mut var, &mut w)
                    .unwrap();
            },
            1,
            4,
        );
        assert_eq!(
            allocs, 0,
            "warm multi-output predict paths (D = 3) allocated {allocs} times"
        );
        assert_eq!(out.shape(), (16, dcols));
        assert!(var.iter().all(|&v| v > 0.0));
    }

    // --- warm serving: the predict_into workspace paths that the serve
    // layer's micro-batch loop runs on must not touch the heap either
    // (1-thread path; batched B=16 reads against every engine kind) ---
    {
        let (x, y) = data(40, 4, 5);
        let (xq, _) = data(16, 4, 6);

        let intr = IntrinsicKrr::fit(&x, &y, &Kernel::poly(2, 1.0), 0.5).unwrap();
        let mut w = IntrinsicPredictWork::default();
        let mut out = Vec::new();
        intr.predict_into(&xq, &mut out, &mut w).unwrap(); // warm
        let allocs =
            steady_state_allocs(|| intr.predict_into(&xq, &mut out, &mut w).unwrap(), 1, 4);
        assert_eq!(allocs, 0, "warm IntrinsicKrr::predict_into allocated {allocs} times");

        // RBF empirical path exercises the Gram norm scratch too
        let emp = EmpiricalKrr::fit(&x, &y, &Kernel::rbf_radius(2.0), 0.5).unwrap();
        let mut we = EmpiricalPredictWork::default();
        emp.predict_into(&xq, &mut out, &mut we).unwrap(); // warm
        let allocs =
            steady_state_allocs(|| emp.predict_into(&xq, &mut out, &mut we).unwrap(), 1, 4);
        assert_eq!(allocs, 0, "warm EmpiricalKrr::predict_into allocated {allocs} times");

        let kbr = KbrModel::fit(&x, &y, &Kernel::poly(2, 1.0), KbrHyper::default()).unwrap();
        let mut wk = KbrPredictWork::default();
        let (mut mean, mut var) = (Vec::new(), Vec::new());
        kbr.predict_into(&xq, &mut mean, &mut var, &mut wk).unwrap(); // warm
        let allocs = steady_state_allocs(
            || kbr.predict_into(&xq, &mut mean, &mut var, &mut wk).unwrap(),
            1,
            4,
        );
        assert_eq!(allocs, 0, "warm KbrModel::predict_into allocated {allocs} times");
        assert!(var.iter().all(|&v| v > 0.0));
    }

    // --- warm sharded serving: the unified query fan-in (snapshot load +
    // K batched shard reads + averaging / precision weighting) through a
    // warm RouterPredictWork is allocation-free end to end — alternating
    // kinds included (the parked variance buffer must survive the
    // point-kind rounds) ---
    {
        use mikrr::coordinator::CoordinatorConfig;
        use mikrr::serve::{
            PredictRequest, PredictResponse, QueryKind, RouterPredictWork, ServeConfig,
            ShardRouter,
        };

        let (x, y) = data(48, 4, 7);
        let (xq, _) = data(16, 4, 8);
        let mut base = CoordinatorConfig::default_for(Kernel::poly(2, 1.0));
        base.outlier = None;
        base.with_uncertainty = true;
        let router = ShardRouter::bootstrap(
            &x,
            &y,
            ServeConfig { shards: 2, placement: mikrr::serve::Placement::RoundRobin, base },
        )
        .unwrap();
        let h = router.handle();
        let mut w = RouterPredictWork::default();
        let mut resp = PredictResponse::default();
        // requests built OUTSIDE the measured loop: the request is the
        // caller's long-lived description of its traffic, not per-call
        // state (PredictRequest::new moves the batch, no copy)
        let req_mean = PredictRequest::new(xq.clone(), QueryKind::Mean);
        let req_var = PredictRequest::new(xq.clone(), QueryKind::MeanVar);
        h.query_into(&req_mean, &mut resp, &mut w).unwrap(); // warm
        h.query_into(&req_var, &mut resp, &mut w).unwrap(); // warm
        let allocs = steady_state_allocs(
            || {
                h.query_into(&req_mean, &mut resp, &mut w).unwrap();
                h.query_into(&req_var, &mut resp, &mut w).unwrap();
            },
            1,
            4,
        );
        assert_eq!(allocs, 0, "warm RouterHandle::query_into allocated {allocs} times");

        // the deprecated *_into shims ride the same workspace and stay on
        // the same zero-allocation contract
        #[allow(deprecated)]
        {
            let mut out = Vec::new();
            let (mut mean, mut var) = (Vec::new(), Vec::new());
            h.predict_into(&xq, &mut out, &mut w).unwrap(); // warm
            h.predict_with_uncertainty_into(&xq, &mut mean, &mut var, &mut w)
                .unwrap(); // warm
            let allocs = steady_state_allocs(
                || {
                    h.predict_into(&xq, &mut out, &mut w).unwrap();
                    h.predict_with_uncertainty_into(&xq, &mut mean, &mut var, &mut w)
                        .unwrap();
                },
                1,
                4,
            );
            assert_eq!(
                allocs, 0,
                "warm deprecated predict_into shims allocated {allocs} times"
            );
        }
    }

    // --- warm health probes (ISSUE 7): the rotating residual probe on the
    // maintained inverse — kernel/scatter row build + GEMV against the
    // inverse + ∞-norm — reuses the probe's own column and residual
    // buffers, so steady-state health checking is free to run every round
    // (both spaces; the sampled columns rotate across checks, exercising
    // fresh probe indices while the buffers stay warm) ---
    {
        use mikrr::config::Space;
        use mikrr::coordinator::engine::Engine;
        use mikrr::health::{HealthProbe, HealthVerdict, ProbeConfig};

        let (x, y) = data(40, 4, 30);
        for space in [Space::Intrinsic, Space::Empirical] {
            let eng = Engine::fit(&x, &y, &Kernel::poly(2, 1.0), 0.5, space, false).unwrap();
            let mut probe = HealthProbe::new(ProbeConfig::default());
            probe.check(&eng).unwrap(); // warm the column + GEMV buffers
            let allocs = steady_state_allocs(
                || {
                    let rep = probe.check(&eng).unwrap();
                    assert_eq!(rep.verdict, HealthVerdict::Healthy);
                },
                1,
                8,
            );
            assert_eq!(
                allocs, 0,
                "warm health probe ({space:?}) allocated {allocs} times"
            );
        }
    }

    // --- warm telemetry (ISSUE 10): every primitive the instrumented
    // round touches — relaxed counter/gauge slots, log₂ histogram
    // buckets, the flight-recorder ring (including wrap-around), and the
    // bucket-backed LatencyHist — is allocation-free once constructed,
    // so wiring registries through the hot paths above cannot perturb
    // their contracts ---
    {
        use mikrr::metrics::LatencyHist;
        use mikrr::telemetry::{FlightRecorder, HistId, MetricId, Registry, SpanKind};

        let reg = Registry::new();
        let mut rec = FlightRecorder::new(64);
        let mut lat = LatencyHist::new(); // buckets built here, never after
        let mut i = 0u64;
        let allocs = steady_state_allocs(
            || {
                i += 1;
                reg.inc(MetricId::Rounds);
                reg.add(MetricId::Routed, 3);
                reg.gauge_max(MetricId::MaxBatchRows, i);
                reg.record_hist(HistId::RoundLatencyUs, i);
                rec.record(SpanKind::IncDec, i, 0);
                lat.record(1e-6 * i as f64);
            },
            4,
            256, // wraps the 64-slot ring well inside the measured window
        );
        assert_eq!(allocs, 0, "warm telemetry primitives allocated {allocs} times");
        assert_eq!(reg.get(MetricId::Rounds), 260);
        assert_eq!((rec.len(), rec.total_recorded()), (64, 260));
        assert_eq!(lat.count(), 260);
    }

    // --- packed BLAS-3 + blocked TRSM, 1-thread path: once the output
    // buffers and the thread-local packing panels are warm, the kernels
    // must not touch the heap either (they sit under every engine above) ---
    {
        use mikrr::linalg::gemm::{dispatch, matmul_into, syrk_into, trsm_lower_into};
        use mikrr::linalg::solve::cholesky_into;

        let n = 160; // over the packed crossover: 160^3 >= 2^21, k >= 32
        assert!(dispatch::use_packed(n, n, n));
        let mut rng = Rng::new(50);
        let a = Mat::from_fn(n, n, |_, _| rng.gaussian());
        let b = Mat::from_fn(n, n, |_, _| rng.gaussian());
        let spd = {
            let mut s = Mat::default();
            syrk_into(1.0 / n as f64, &a, 0.0, &mut s).unwrap();
            s.add_diag(1.0).unwrap();
            s
        };
        let mut c = Mat::default();
        let mut l = Mat::default();
        let mut rhs = b.clone();
        // warm: packing panels, output scratch, factor buffer
        matmul_into(&a, &b, &mut c).unwrap();
        cholesky_into(&spd, &mut l).unwrap();
        trsm_lower_into(&l, false, &mut rhs).unwrap();
        let allocs = steady_state_allocs(
            || {
                matmul_into(&a, &b, &mut c).unwrap();
                syrk_into(1.0, &a, 0.0, &mut c).unwrap();
                cholesky_into(&spd, &mut l).unwrap();
                trsm_lower_into(&l, false, &mut rhs).unwrap();
            },
            1,
            3,
        );
        assert_eq!(
            allocs, 0,
            "warm packed gemm/syrk/cholesky/trsm allocated {allocs} times"
        );
    }

    // --- packed parallel LU panel path, 1-thread: the full blocked LU
    // (panel pivot search, lazy swaps, ger_panel updates, packed trailing
    // GEMM) reuses the caller's Lu buffers and keeps its pivot scratch on
    // the stack — zero heap traffic once warm ---
    {
        use mikrr::linalg::solve::{lu_decompose_into, Lu};

        // n=256: the first panel's trailing update (192·192·64 ≈ 2.4M
        // multiply-adds, k=64) sits over the packed-dispatch crossover
        let n = 256;
        let mut rng = Rng::new(51);
        let g = Mat::from_fn(n, n, |r, c| {
            rng.gaussian() + if r == c { 4.0 } else { 0.0 }
        });
        let mut lu = Lu::default();
        lu_decompose_into(&g, &mut lu).unwrap(); // warm the factor + perm
        let allocs = steady_state_allocs(|| lu_decompose_into(&g, &mut lu).unwrap(), 1, 3);
        assert_eq!(
            allocs, 0,
            "warm packed LU panel path allocated {allocs} times"
        );
        assert_eq!(lu.perm.len(), n);
    }
}
