//! Pool-geometry freeze regression: the lane count AND the pin map are
//! read from the environment once, together, before the first dispatch —
//! a mid-process `MIKRR_THREADS`/`MIKRR_PIN` change must never desync
//! chunk claiming from the pinned cores (the bug class this guards: a
//! pool built for N lanes claiming chunks with a later M-lane slot
//! partition).
//!
//! Everything lives in ONE `#[test]` in its own binary: the env mutations
//! must happen before any sibling test touches a parallel code path, and
//! integration-test binaries are separate processes, so this cannot
//! interfere with the rest of the suite.

use std::sync::atomic::{AtomicU64, Ordering};

#[test]
fn geometry_is_frozen_before_first_dispatch() {
    // must run before ANY parallel call: the geometry caches on first use
    #[allow(unused_unsafe)]
    unsafe {
        std::env::set_var("MIKRR_THREADS", "3");
    }
    assert_eq!(mikrr::par::num_threads(), 3);
    let pinned0 = mikrr::par::pinned_lanes();
    // at most one pin target per spawned worker (2 here); possibly 0 when
    // pinning is unsupported or the host is single-core
    assert!(pinned0 <= 2, "pinned_lanes {pinned0} > workers");

    // drive the pool once so it is built on the frozen geometry
    let warm = AtomicU64::new(0);
    mikrr::par::parallel_for(256, 1, |lo, hi| {
        warm.fetch_add((hi - lo) as u64, Ordering::Relaxed);
    });
    assert_eq!(warm.load(Ordering::Relaxed), 256);

    // mid-process override attempts must be inert: the lane count and the
    // pin map were frozen together at first use
    #[allow(unused_unsafe)]
    unsafe {
        std::env::set_var("MIKRR_THREADS", "9");
        std::env::set_var("MIKRR_PIN", "0");
    }
    assert_eq!(mikrr::par::num_threads(), 3, "lane count must stay frozen");
    assert_eq!(
        mikrr::par::pinned_lanes(),
        pinned0,
        "pin map must stay frozen with the lane count"
    );

    // dispatches keep completing with exact coverage on the frozen
    // geometry (a desynced slot partition would drop or double indices)
    for n in [1usize, 7, 64, 257, 1000] {
        for _ in 0..50 {
            let counter = AtomicU64::new(0);
            mikrr::par::parallel_for(n, 1, |lo, hi| {
                for i in lo..hi {
                    counter.fetch_add(i as u64 + 1, Ordering::Relaxed);
                }
            });
            let expect: u64 = (1..=n as u64).sum();
            assert_eq!(counter.load(Ordering::Relaxed), expect, "n={n}");
        }
    }
}
