//! Cross-module integration tests: experiment driver, accuracy invariance
//! at realistic (scaled) sizes, advisor routing, end-to-end performance
//! ordering (multiple < single per round).

use mikrr::config::Space;
use mikrr::coordinator::experiment::{run_kbr, run_krr, Strategy};
use mikrr::data::synth;
use mikrr::kbr::KbrHyper;
use mikrr::kernels::Kernel;
use mikrr::krr::advisor::Advisor;
use mikrr::krr::{classification_accuracy, KrrModel};

#[test]
fn ecg_poly2_all_strategies_agree_and_multiple_wins() {
    let data = synth::ecg_like(1800, 21, 11);
    let report = run_krr(
        &data,
        &Kernel::poly(2, 1.0),
        0.5,
        Space::Intrinsic,
        1200,
        5,
        4,
        2,
        11,
        &[Strategy::Multiple, Strategy::Single, Strategy::None],
    )
    .unwrap();
    assert!(report.strategies_agree, "strategies disagree");
    assert!(report.accuracy > 0.85, "accuracy {}", report.accuracy);
    // the paper's ordering: multiple < single < none per-round mean
    let m = report.record.mean_seconds("multiple");
    let s = report.record.mean_seconds("single");
    let n = report.record.mean_seconds("none");
    assert!(m < s, "multiple {m} !< single {s}");
    assert!(s < n, "single {s} !< none {n}");
}

#[test]
fn drt_rbf_empirical_strategies_agree() {
    let data = synth::drt_like(360, 2_000, 0.01, 12);
    let report = run_krr(
        &data,
        &Kernel::rbf_radius(50.0),
        0.5,
        Space::Empirical,
        240,
        5,
        4,
        2,
        12,
        &[Strategy::Multiple, Strategy::Single, Strategy::None],
    )
    .unwrap();
    assert!(report.strategies_agree);
    let m = report.record.mean_seconds("multiple");
    let n = report.record.mean_seconds("none");
    assert!(m < n, "multiple {m} !< none {n}");
}

#[test]
fn kbr_multiple_beats_single() {
    let data = synth::ecg_like(900, 21, 13);
    let report = run_kbr(
        &data,
        &Kernel::poly(2, 1.0),
        KbrHyper::default(),
        600,
        5,
        4,
        2,
        13,
        true,
    )
    .unwrap();
    assert!(report.strategies_agree);
    let m = report.record.mean_seconds("multiple");
    let s = report.record.mean_seconds("single");
    assert!(m < s, "multiple {m} !< single {s}");
}

#[test]
fn advisor_routes_paper_regimes() {
    let adv = Advisor::default();
    // ECG: N >> M -> intrinsic for poly kernels
    assert_eq!(
        adv.choose_space(&Kernel::poly(2, 1.0), 83_226, 21, 4, 2).space,
        Space::Intrinsic
    );
    // DRT: M >> N -> empirical
    assert_eq!(
        adv.choose_space(&Kernel::poly(2, 1.0), 640, 1_000_000, 4, 2).space,
        Space::Empirical
    );
    // RBF always empirical
    assert_eq!(
        adv.choose_space(&Kernel::rbf_radius(50.0), 83_226, 21, 4, 2).space,
        Space::Empirical
    );
}

#[test]
fn forgetting_long_stream_stays_numerically_sound() {
    // 40 rounds of +4/-2 on one engine: the maintained inverse must not
    // drift (predictions stay finite and accurate).
    use mikrr::krr::intrinsic::IntrinsicKrr;
    let data = synth::ecg_like(1000, 10, 14);
    let base = data.subset(&(0..500).collect::<Vec<_>>());
    let mut model = IntrinsicKrr::fit(&base.x, &base.y, &Kernel::poly(2, 1.0), 0.5).unwrap();
    let mut rng = mikrr::util::prng::Rng::new(14);
    let mut next = 500;
    for _ in 0..40 {
        let idx: Vec<usize> = (next..next + 4).collect();
        next += 4;
        if next + 4 > data.len() {
            break;
        }
        let rem = rng.sample_indices(model.n_samples(), 2);
        model
            .inc_dec(&data.x.select_rows(&idx), &data.y_rows(&idx), &rem)
            .unwrap();
    }
    assert!(model.s_inv().is_finite(), "maintained inverse drifted to non-finite");
    let test = synth::ecg_like(400, 10, 15);
    let pred = model.predict(&test.x).unwrap();
    let acc = classification_accuracy(&pred, &test.y);
    assert!(acc > 0.80, "accuracy after 40 rounds {acc}");
}

#[test]
fn failure_injection_invalid_rounds_leave_engine_usable() {
    use mikrr::krr::empirical::EmpiricalKrr;
    use mikrr::linalg::Mat;
    let data = synth::ecg_like(100, 6, 16);
    let mut model = EmpiricalKrr::fit(&data.x, &data.y, &Kernel::rbf_radius(2.0), 0.5).unwrap();
    // invalid removal index must error but not poison the state
    assert!(model.inc_dec(&Mat::zeros(0, 6), &[], &[999]).is_err());
    let extra = synth::ecg_like(4, 6, 17);
    model.inc_dec(&extra.x, &extra.y, &[0]).unwrap();
    assert_eq!(model.n_samples(), 103);
    let pred = model.predict(&data.x).unwrap();
    assert!(pred.iter().all(|v| v.is_finite()));
}
