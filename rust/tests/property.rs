//! Property-based tests over the paper's mathematical invariants
//! (DESIGN.md §7), using the seeded `testutil::Cases` harness.
//!
//! Replay a failing case with `Cases::only(<seed>)` — the failure message
//! carries the seed.

use mikrr::kbr::{KbrHyper, KbrModel};
use mikrr::kernels::Kernel;
use mikrr::krr::empirical::EmpiricalKrr;
use mikrr::krr::intrinsic::IntrinsicKrr;
use mikrr::krr::KrrModel;
use mikrr::linalg::gemm::ger;
use mikrr::linalg::solve::spd_inverse;
use mikrr::linalg::woodbury::{
    bordered_grow, bordered_grow_into, bordered_shrink, bordered_shrink_into, incdec,
    incdec_into, sub_matrix, BorderWork, IncDecWork,
};
use mikrr::linalg::Mat;
use mikrr::testutil::{assert_mat_close, assert_vec_close, random_mat, random_spd, Cases};
use mikrr::util::prng::Rng;

fn random_regression(rng: &mut Rng, n: usize, m: usize) -> (Mat, Vec<f64>) {
    let w = rng.gaussian_vec(m);
    let x = random_mat(rng, n, m, 0.5);
    let y: Vec<f64> = (0..n)
        .map(|i| mikrr::linalg::matrix::dot(x.row(i), &w) + 0.05 * rng.gaussian())
        .collect();
    (x, y)
}

/// eq. 15: batched Woodbury up/down-date == fresh inverse of the updated S.
#[test]
fn prop_woodbury_incdec_matches_fresh_inverse() {
    Cases::new(40, 0xA1).run(|rng| {
        let j = 3 + rng.below(40);
        let nc = rng.below(7);
        let nr = rng.below(4);
        if nc + nr == 0 {
            return;
        }
        let s = random_spd(rng, j, j as f64);
        let s_inv = spd_inverse(&s).unwrap();
        let phi = random_mat(rng, j, nc + nr, 0.25);
        let mut signs = vec![1.0; nc];
        signs.extend(vec![-1.0; nr]);
        let got = incdec(&s_inv, &phi, &signs).unwrap();
        let mut s_new = s.clone();
        for h in 0..nc + nr {
            let col = phi.col(h);
            ger(&mut s_new, signs[h], &col, &col).unwrap();
        }
        let want = spd_inverse(&s_new).unwrap();
        assert_mat_close(&got, &want, 1e-6);
    });
}

/// inc(C) followed by dec(C) of the same columns is the identity.
#[test]
fn prop_incdec_roundtrip_identity() {
    Cases::new(30, 0xA2).run(|rng| {
        let j = 2 + rng.below(30);
        let k = 1 + rng.below(5);
        let s_inv = spd_inverse(&random_spd(rng, j, 2.0 * j as f64)).unwrap();
        let phi = random_mat(rng, j, k, 0.2);
        let up = incdec(&s_inv, &phi, &vec![1.0; k]).unwrap();
        let back = incdec(&up, &phi, &vec![-1.0; k]).unwrap();
        assert_mat_close(&back, &s_inv, 1e-7);
    });
}

/// eq. 28/29: bordered grow + shrink against fresh inverses, any index set.
#[test]
fn prop_bordered_grow_shrink_match_fresh() {
    Cases::new(30, 0xA3).run(|rng| {
        let n = 4 + rng.below(20);
        let c = 1 + rng.below(4);
        let full = random_spd(rng, n + c, (n + c) as f64);
        let q = full.block(0, n, 0, n);
        let eta = full.block(0, n, n, n + c);
        let qcc = full.block(n, n + c, n, n + c);
        let grown = bordered_grow(&spd_inverse(&q).unwrap(), &eta, &qcc).unwrap();
        assert_mat_close(&grown, &spd_inverse(&full).unwrap(), 1e-6);

        // shrink a random subset
        let r = 1 + rng.below(n / 2);
        let rem = {
            let mut v = rng.sample_indices(n + c, r);
            v.sort_unstable();
            v
        };
        let shrunk = bordered_shrink(&grown, &rem).unwrap();
        let keep: Vec<usize> = (0..n + c).filter(|i| !rem.contains(i)).collect();
        let want = spd_inverse(&sub_matrix(&full, &keep, &keep)).unwrap();
        assert_mat_close(&shrunk, &want, 1e-6);
    });
}

/// The central claim: multiple inc/dec == retrain, intrinsic space.
#[test]
fn prop_intrinsic_incdec_equals_retrain() {
    Cases::new(15, 0xA4).run(|rng| {
        let m = 2 + rng.below(5);
        let n = 25 + rng.below(30);
        let kernel = Kernel::poly(2, 1.0);
        let (x, y) = random_regression(rng, n, m);
        let mut model = IntrinsicKrr::fit(&x, &y, &kernel, 0.5).unwrap();
        let nc = 1 + rng.below(6);
        let (xc, yc) = random_regression(rng, nc, m);
        let rem = {
            let k = rng.below(3).min(n - 1);
            let mut v = rng.sample_indices(n, k);
            v.sort_unstable();
            v
        };
        model.inc_dec(&xc, &yc, &rem).unwrap();

        let mut x2 = x.clone();
        let mut y2 = y.clone();
        x2.remove_rows(&rem).unwrap();
        for (i, &ri) in rem.iter().enumerate() {
            y2.remove(ri - i);
        }
        let x2 = x2.vcat(&xc).unwrap();
        y2.extend_from_slice(&yc);
        let fresh = IntrinsicKrr::fit(&x2, &y2, &kernel, 0.5).unwrap();
        assert_vec_close(model.weights(), fresh.weights(), 1e-6);
    });
}

/// Same claim in empirical space, including RBF kernels.
#[test]
fn prop_empirical_incdec_equals_retrain() {
    Cases::new(12, 0xA5).run(|rng| {
        let m = 2 + rng.below(5);
        let n = 20 + rng.below(20);
        let kernel = if rng.coin(0.5) {
            Kernel::rbf_radius(2.0)
        } else {
            Kernel::poly(3, 1.0)
        };
        let (x, y) = random_regression(rng, n, m);
        let mut model = EmpiricalKrr::fit(&x, &y, &kernel, 0.5).unwrap();
        let nc = 1 + rng.below(5);
        let (xc, yc) = random_regression(rng, nc, m);
        let rem = {
            let k = rng.below(3).min(n - 1);
            let mut v = rng.sample_indices(n, k);
            v.sort_unstable();
            v
        };
        model.inc_dec(&xc, &yc, &rem).unwrap();

        let mut x2 = x.clone();
        let mut y2 = y.clone();
        x2.remove_rows(&rem).unwrap();
        for (i, &ri) in rem.iter().enumerate() {
            y2.remove(ri - i);
        }
        let x2 = x2.vcat(&xc).unwrap();
        y2.extend_from_slice(&yc);
        let fresh = EmpiricalKrr::fit(&x2, &y2, &kernel, 0.5).unwrap();
        assert_vec_close(model.dual_weights(), fresh.dual_weights(), 1e-5);
    });
}

/// Intrinsic and empirical modes are the same estimator for poly kernels.
#[test]
fn prop_modes_agree_for_poly() {
    Cases::new(12, 0xA6).run(|rng| {
        let m = 2 + rng.below(4);
        let n = 20 + rng.below(20);
        let (x, y) = random_regression(rng, n, m);
        let (xt, _) = random_regression(rng, 8, m);
        let kernel = Kernel::poly(2, 1.0);
        let intr = IntrinsicKrr::fit(&x, &y, &kernel, 0.5).unwrap();
        let emp = EmpiricalKrr::fit(&x, &y, &kernel, 0.5).unwrap();
        let pi = intr.predict(&xt).unwrap();
        let pe = emp.predict(&xt).unwrap();
        assert_vec_close(&pi, &pe, 1e-5);
    });
}

/// KBR incremental posterior == batch posterior on the edited set.
#[test]
fn prop_kbr_incremental_equals_batch() {
    Cases::new(10, 0xA7).run(|rng| {
        let m = 2 + rng.below(4);
        let n = 15 + rng.below(20);
        let kernel = Kernel::poly(2, 1.0);
        let (x, y) = random_regression(rng, n, m);
        let nc = 1 + rng.below(5);
        let (xc, yc) = random_regression(rng, nc, m);
        let mut inc = KbrModel::fit(&x, &y, &kernel, KbrHyper::default()).unwrap();
        let rem = {
            let k = rng.below(3).min(n - 1);
            let mut v = rng.sample_indices(n, k);
            v.sort_unstable();
            v
        };
        inc.inc_dec(&xc, &yc, &rem).unwrap();

        let mut x2 = x.clone();
        let mut y2 = y.clone();
        x2.remove_rows(&rem).unwrap();
        for (i, &ri) in rem.iter().enumerate() {
            y2.remove(ri - i);
        }
        let x2 = x2.vcat(&xc).unwrap();
        y2.extend_from_slice(&yc);
        let batch = KbrModel::fit(&x2, &y2, &kernel, KbrHyper::default()).unwrap();
        assert_vec_close(inc.posterior_mean(), batch.posterior_mean(), 1e-5);
        assert_mat_close(inc.posterior_cov(), batch.posterior_cov(), 1e-5);
    });
}

/// One fused +C/−R round == dec-then-inc as separate batched ops
/// (eq. 30's ordering composes with eq. 15).
#[test]
fn prop_fused_round_equals_sequential_batches() {
    Cases::new(12, 0xA8).run(|rng| {
        let m = 3;
        let n = 25 + rng.below(15);
        let kernel = Kernel::poly(2, 1.0);
        let (x, y) = random_regression(rng, n, m);
        let (xc, yc) = random_regression(rng, 4, m);
        let rem = {
            let mut v = rng.sample_indices(n, 2);
            v.sort_unstable();
            v
        };
        let mut fused = IntrinsicKrr::fit(&x, &y, &kernel, 0.5).unwrap();
        fused.inc_dec(&xc, &yc, &rem).unwrap();
        let mut seq = IntrinsicKrr::fit(&x, &y, &kernel, 0.5).unwrap();
        seq.inc_dec(&Mat::zeros(0, m), &[], &rem).unwrap();
        seq.inc_dec(&xc, &yc, &[]).unwrap();
        assert_vec_close(fused.weights(), seq.weights(), 1e-7);
    });
}

/// Long-horizon drift: ONE maintained inverse pushed through 120
/// alternating grow / incdec / shrink rounds of the in-place engine,
/// sharing one BorderWork + IncDecWork throughout. After every round the
/// inverse must be exactly symmetric (each update symmetrizes); every
/// tenth round it must still agree with a fresh inverse of the explicitly
/// tracked matrix.
#[test]
fn prop_long_horizon_grow_shrink_incdec_drift() {
    let mut rng = Rng::new(0xD0);
    let n0 = 24;
    // S kept explicitly (the ground truth); s_inv maintained incrementally
    let mut s_full = random_spd(&mut rng, n0, 40.0);
    let mut s_inv = spd_inverse(&s_full).unwrap();
    let mut border = BorderWork::default();
    let mut incwork = IncDecWork::default();
    for round in 0..120 {
        let n = s_full.rows();
        match round % 3 {
            0 => {
                // grow by 2: extend S with a diagonally dominant block so
                // the bordered system stays SPD
                let eta = random_mat(&mut rng, n, 2, 0.2);
                let mut qcc = random_mat(&mut rng, 2, 2, 0.2);
                qcc.symmetrize();
                qcc.add_diag(40.0).unwrap();
                bordered_grow_into(&mut s_inv, &eta, &qcc, &mut border).unwrap();
                s_full.grow_inplace(n + 2, n + 2).unwrap();
                for r in 0..n {
                    for c in 0..2 {
                        s_full[(r, n + c)] = eta[(r, c)];
                        s_full[(n + c, r)] = eta[(r, c)];
                    }
                }
                for r in 0..2 {
                    for c in 0..2 {
                        s_full[(n + r, n + c)] = qcc[(r, c)];
                    }
                }
            }
            1 => {
                // rank-4 incdec: +2/−2 small columns (S stays PD: the
                // downdate norm is far below the diagonal dominance)
                let phi = random_mat(&mut rng, n, 4, 0.15);
                let signs = [1.0, 1.0, -1.0, -1.0];
                incdec_into(&mut s_inv, &phi, &signs, &mut incwork).unwrap();
                for h in 0..4 {
                    let col = phi.col(h);
                    ger(&mut s_full, signs[h], &col, &col).unwrap();
                }
            }
            _ => {
                // shrink by 2 random distinct indices (size returns to n0)
                let i0 = rng.below(n);
                let mut i1 = rng.below(n);
                if i1 == i0 {
                    i1 = (i1 + 1) % n;
                }
                let mut rem = [i0, i1];
                rem.sort_unstable();
                bordered_shrink_into(&mut s_inv, &rem, &mut border).unwrap();
                let keep: Vec<usize> =
                    (0..n).filter(|i| !rem.contains(i)).collect();
                s_full.compact(&keep, &keep).unwrap();
            }
        }
        assert_eq!(s_inv.shape(), s_full.shape(), "round {round}");
        // exact symmetry: every in-place update ends in symmetrize()
        let sym_err = s_inv.max_abs_diff(&s_inv.transpose());
        assert!(sym_err < 1e-15, "round {round}: symmetry drift {sym_err:.3e}");
        if round % 10 == 9 || round == 119 {
            let fresh = spd_inverse(&s_full).unwrap();
            assert_mat_close(&s_inv, &fresh, 1e-6);
        }
    }
}

/// The two KRR spaces agree through whole update sequences, not just fits.
#[test]
fn prop_spaces_agree_through_updates() {
    Cases::new(8, 0xA9).run(|rng| {
        let m = 3;
        let n = 20;
        let kernel = Kernel::poly(2, 1.0);
        let (x, y) = random_regression(rng, n, m);
        let (xt, _) = random_regression(rng, 6, m);
        let mut intr = IntrinsicKrr::fit(&x, &y, &kernel, 0.5).unwrap();
        let mut emp = EmpiricalKrr::fit(&x, &y, &kernel, 0.5).unwrap();
        let mut n_cur = n;
        for _ in 0..3 {
            let (xc, yc) = random_regression(rng, 3, m);
            let rem = {
                let mut v = rng.sample_indices(n_cur, 1);
                v.sort_unstable();
                v
            };
            intr.inc_dec(&xc, &yc, &rem).unwrap();
            emp.inc_dec(&xc, &yc, &rem).unwrap();
            n_cur += 2;
        }
        let pi = intr.predict(&xt).unwrap();
        let pe = emp.predict(&xt).unwrap();
        assert_vec_close(&pi, &pe, 1e-5);
    });
}
