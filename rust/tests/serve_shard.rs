//! Acceptance tests for the sharded serving layer (ISSUE 5):
//!
//! * **Shard parity** — K-shard averaged predictions track the
//!   single-engine baseline within the documented DC-KRR averaging
//!   tolerance, across seeds.
//! * **Epoch serving** — predictions keep flowing (from the last
//!   published epoch) while shard updates are in flight; readers never
//!   block on or observe a half-applied update.
//! * **End-to-end** — stream → router → shard rounds bookkeeping.

// The serving tests intentionally exercise the deprecated predict*
// shims alongside the unified query API.
#![allow(deprecated)]

use mikrr::data::synth;
use mikrr::kernels::Kernel;
use mikrr::krr::rmse;
use mikrr::linalg::matrix::dot;
use mikrr::linalg::Mat;
use mikrr::serve::{
    MicroBatchPolicy, MicroBatchServer, Placement, RetryPolicy, ServeConfig, ShardRouter,
    ShardStatus, ShardSupervisor, SupervisorConfig,
};
use mikrr::streaming::sink::SinkNode;
use mikrr::streaming::source::{SensorNode, SourceConfig};
use mikrr::streaming::StreamEvent;
use mikrr::util::prng::Rng;
use std::time::Duration;

/// Low-noise near-linear data (the regime where the DC-KRR averaging
/// argument is quantitatively tight).
fn data(n: usize, m: usize, seed: u64) -> (Mat, Vec<f64>) {
    let mut rng = Rng::new(seed);
    let w: Vec<f64> = rng.gaussian_vec(m);
    let x = Mat::from_fn(n, m, |_, _| 0.5 * rng.gaussian());
    let y: Vec<f64> = (0..n)
        .map(|i| dot(x.row(i), &w) + 0.05 * rng.gaussian())
        .collect();
    (x, y)
}

fn serve_cfg(shards: usize, uncertainty: bool) -> ServeConfig {
    let mut cfg = ServeConfig::default_for(Kernel::poly(2, 1.0), shards);
    cfg.base.outlier = None;
    cfg.base.with_uncertainty = uncertainty;
    cfg
}

/// Shard-parity property: K-shard averaged predictions vs the
/// single-engine baseline.
///
/// Tolerance, from the DC-KRR averaging argument (You et al.): with the
/// bootstrap set split uniformly (row i → shard i mod K), each shard's
/// KRR estimate is an independent, unbiased estimate of the same
/// regression function, fitted on N/K samples. The averaged prediction
/// therefore deviates from the full-data solution by the per-shard
/// estimation error shrunk by the averaging — on this low-noise synthetic
/// (signal std ≈ 1.2, noise 0.05, N/K = 60 ≫ J = 28) that is a few
/// percent of the signal scale. We assert a 0.30 RMSE envelope (≈ 25% of
/// signal std) between sharded and single-engine predictions, and that
/// held-out accuracy does not degrade past 1.5× the baseline error — both
/// far above the expected deviation but far below what any bug that broke
/// the averaging (wrong weights, double-counted bias, missing shard)
/// would produce.
#[test]
fn kshard_parity_with_single_engine_baseline() {
    for seed in [1u64, 7, 42] {
        let (x, y) = data(240, 6, seed);
        let (xq, yq) = data(40, 6, 1000 + seed);
        let router = ShardRouter::bootstrap(&x, &y, serve_cfg(4, false)).unwrap();
        let single = mikrr::coordinator::engine::Engine::fit(
            &x,
            &y,
            &Kernel::poly(2, 1.0),
            0.5,
            router.space(),
            false,
        )
        .unwrap();
        let sharded = router.handle().predict(&xq).unwrap();
        let baseline = single.predict(&xq).unwrap();

        let dev = rmse(&sharded, &baseline);
        assert!(dev < 0.30, "seed {seed}: sharded-vs-single rmse {dev}");

        let err_sharded = rmse(&sharded, &yq);
        let err_single = rmse(&baseline, &yq);
        assert!(
            err_sharded < 1.5 * err_single + 0.05,
            "seed {seed}: held-out rmse degraded {err_sharded} vs {err_single}"
        );
        // and the sharded model genuinely learned the function (signal
        // std is ~1.2 here; predicting 0 would score ~1.2)
        assert!(err_sharded < 0.6, "seed {seed}: sharded held-out rmse {err_sharded}");
    }
}

/// Precision-weighted uncertainty fan-in: fused variance stays on a
/// single-model scale, brackets the noise floor, and the fused mean stays
/// inside the envelope of the shard means.
#[test]
fn kshard_uncertainty_fanin_is_calibrated() {
    let (x, y) = data(240, 5, 3);
    let (xq, _) = data(12, 5, 1003);
    let router = ShardRouter::bootstrap(&x, &y, serve_cfg(4, true)).unwrap();
    let h = router.handle();
    let (mu, var) = h.predict_with_uncertainty(&xq).unwrap();
    // per-shard posteriors for the envelope check
    let mut shard_means: Vec<Vec<f64>> = Vec::new();
    let mut shard_vars: Vec<Vec<f64>> = Vec::new();
    for s in 0..4 {
        let (m, v) = h.shard(s).predict_with_uncertainty(&xq).unwrap();
        shard_means.push(m);
        shard_vars.push(v);
    }
    for i in 0..xq.rows() {
        let noise = 0.01; // KbrHyper::default().sigma_b2
        assert!(var[i] >= noise - 1e-12, "fused var under the noise floor");
        let lo = (0..4).map(|s| shard_means[s][i]).fold(f64::INFINITY, f64::min);
        let hi = (0..4).map(|s| shard_means[s][i]).fold(f64::NEG_INFINITY, f64::max);
        assert!(lo - 1e-12 <= mu[i] && mu[i] <= hi + 1e-12, "fused mean outside envelope");
        // fused variance is the precision-weighted harmonic mean of the
        // shard variances: bounded by the shard extremes
        let vlo = (0..4).map(|s| shard_vars[s][i]).fold(f64::INFINITY, f64::min);
        let vhi = (0..4).map(|s| shard_vars[s][i]).fold(f64::NEG_INFINITY, f64::max);
        assert!(vlo - 1e-12 <= var[i] && var[i] <= vhi + 1e-12);
    }
}

/// The epoch-publish acceptance test: a writer thread drives fused update
/// rounds while the main thread hammers the read handle. Every read must
/// succeed (served from the last published epoch — never blocked, never a
/// torn state), epochs must advance monotonically, and reads must keep
/// landing throughout the update storm.
#[test]
fn reads_served_continuously_while_updates_in_flight() {
    let (x, y) = data(300, 5, 4);
    let router = ShardRouter::bootstrap(&x, &y, serve_cfg(1, false)).unwrap();
    let h = router.handle();
    let (xq, _) = data(8, 5, 1004);

    let rounds = 25usize;
    let mut reads = 0u64;
    let mut last_epoch = 0u64;
    let mut epochs_seen = std::collections::BTreeSet::new();
    let read_once = |last_epoch: &mut u64,
                         epochs_seen: &mut std::collections::BTreeSet<u64>,
                         reads: &mut u64| {
        let (snap, epoch) = h.shard(0).snapshot_with_epoch();
        let p = snap.predict(&xq).unwrap();
        assert_eq!(p.len(), 8);
        assert!(p.iter().all(|v| v.is_finite()), "torn/garbage state read");
        assert!(epoch >= *last_epoch, "epoch went backwards: {epoch} < {last_epoch}");
        *last_epoch = epoch;
        epochs_seen.insert(epoch);
        *reads += 1;
    };
    // one read against the bootstrap epoch (deterministically pre-final;
    // the strictly-during-an-update read is pinned deterministically by
    // serve::publish's barrier test)
    read_once(&mut last_epoch, &mut epochs_seen, &mut reads);

    let writer = {
        let mut router = router;
        std::thread::spawn(move || {
            for r in 0..rounds {
                let (xc, yc) = data(4, 5, 2000 + r as u64);
                let rem: Vec<usize> = (0..4).collect();
                router.shard_mut(0).apply_update(&xc, &yc, &rem).unwrap();
            }
            router
        })
    };

    while h.shard(0).epoch() < rounds as u64 {
        read_once(&mut last_epoch, &mut epochs_seen, &mut reads);
    }
    let router = writer.join().unwrap();
    assert_eq!(h.shard(0).epoch(), rounds as u64);
    assert!(reads > 0, "no reads landed during the update storm");
    assert!(
        epochs_seen.iter().any(|&e| e < rounds as u64),
        "reader never observed a pre-final epoch"
    );
    assert_eq!(router.n_samples(), 300);
}

/// Stream → fan-out → per-shard sinks → router rounds, end to end, with
/// hash placement and an explicit outlier-eviction round.
#[test]
fn router_runs_a_stream_end_to_end() {
    let (x, y) = data(160, 6, 5);
    let mut cfg = serve_cfg(2, false);
    cfg.placement = Placement::Hash;
    cfg.base.outlier = Some(mikrr::streaming::outlier::OutlierConfig {
        z_threshold: 6.0,
        max_removals: 1,
    });
    let mut router = ShardRouter::bootstrap(&x, &y, cfg).unwrap();
    let n0 = router.n_samples();

    let mut sink = SinkNode::new(32);
    let streamed = synth::ecg_like(30, 6, 6);
    let handle = SensorNode::new(streamed, SourceConfig::default()).spawn(sink.sender());
    sink.seal();

    let report = router.run(&mut sink, 1000);
    handle.join().unwrap();
    assert!(report.errors.is_empty(), "{:?}", report.errors);
    let (added, removed) = (report.added(), report.removed());
    assert_eq!(added, 30);
    assert_eq!(router.n_samples(), n0 + added - removed);
    assert_eq!(router.counters().get("routed"), 30);
    assert!(router.shard(0).pending() == 0 && router.shard(1).pending() == 0);

    // one explicit decremental round across every shard
    let n_before = router.n_samples();
    let evict = router.evict_outliers();
    assert!(evict.errors.is_empty());
    assert_eq!(router.n_samples(), n_before - evict.removed());
    // the epoch advanced on every shard (insertion-free rounds publish too)
    assert!(router.handle().epochs().iter().all(|&e| e >= 1));
}

/// Micro-batched serving across threads agrees with the direct batched
/// read path on every single-row request.
#[test]
fn microbatch_server_matches_direct_reads() {
    let (x, y) = data(120, 5, 8);
    let router = ShardRouter::bootstrap(&x, &y, serve_cfg(2, true)).unwrap();
    let h = router.handle();
    let (xq, _) = data(24, 5, 1008);
    let direct = h.predict(&xq).unwrap();
    let (dmu, dvar) = h.predict_with_uncertainty(&xq).unwrap();

    let server = MicroBatchServer::spawn(h, 5, MicroBatchPolicy::default());
    let mut joins = Vec::new();
    for t in 0..3usize {
        let mut client = server.client();
        let rows: Vec<Vec<f64>> = (0..8).map(|i| xq.row(t * 8 + i).to_vec()).collect();
        joins.push(std::thread::spawn(move || {
            rows.iter()
                .map(|r| client.predict_with_uncertainty(r).unwrap())
                .collect::<Vec<(f64, f64)>>()
        }));
    }
    for (t, j) in joins.into_iter().enumerate() {
        for (i, (m, v)) in j.join().unwrap().into_iter().enumerate() {
            let idx = t * 8 + i;
            assert!((m - dmu[idx]).abs() < 1e-9, "mean mismatch at {idx}");
            assert!((v - dvar[idx]).abs() < 1e-9, "var mismatch at {idx}");
            assert!((m - direct[idx]).abs() < 1.0, "sanity: mean near point estimate");
        }
    }
    let stats = server.shutdown();
    assert_eq!(stats.requests, 24);
}

/// Malformed events must be rejected at the shard boundary — counted,
/// dropped, and never allowed to reach (or corrupt) the engines or the
/// published epochs.
#[test]
fn bad_event_does_not_corrupt_published_state() {
    let (x, y) = data(60, 5, 9);
    let mut router = ShardRouter::bootstrap(&x, &y, serve_cfg(1, false)).unwrap();
    let h = router.handle();
    let (xq, _) = data(4, 5, 1009);
    let p0 = h.predict(&xq).unwrap();
    router.ingest(StreamEvent::single(vec![0.0; 2], 1.0, 0, 0));
    let report = router.update_round();
    assert!(report.is_empty(), "a rejected event is not a round: {report:?}");
    assert_eq!(h.epochs(), vec![0], "rejected event must not publish");
    assert_eq!(router.shard(0).pending(), 0, "malformed event discarded");
    assert_eq!(router.shard(0).counters().get("rejected"), 1);
    let p1 = h.predict(&xq).unwrap();
    for (a, b) in p0.iter().zip(&p1) {
        assert_eq!(a, b, "published state changed after a rejected event");
    }
    // direct apply_batch still surfaces the shape error to explicit callers
    let bad = StreamEvent::single(vec![0.0; 2], 1.0, 0, 1);
    assert!(router.shard_mut(0).apply_batch(&[bad]).is_err());
}

/// Multi-output targets derived from one scalar stream (D calibrated
/// transforms of the same signal).
fn multi_targets(y: &[f64], d: usize) -> Mat {
    Mat::from_fn(y.len(), d, |i, j| (1.0 + 0.5 * j as f64) * y[i])
}

/// Satellite 3 — shard-permutation invariance. Both fan-in estimators are
/// order-free reductions (DC-KRR: a sum divided by K; KBR: precision-
/// weighted sums), so serving the same query through any permutation of
/// the shard handles must agree to 1e-12. Seed-matrixed: three bootstrap
/// seeds × three permutations each (reverse, rotation, and a fixed
/// shuffle).
#[test]
fn fanin_is_invariant_under_shard_permutation() {
    for seed in [11u64, 29, 53] {
        let (x, y) = data(240, 5, seed);
        let (xq, _) = data(16, 5, 2000 + seed);
        let router = ShardRouter::bootstrap(&x, &y, serve_cfg(4, true)).unwrap();
        let h = router.handle();
        let base_mean = h.predict(&xq).unwrap();
        let (base_mu, base_var) = h.predict_with_uncertainty(&xq).unwrap();
        for order in [[3usize, 2, 1, 0], [1, 2, 3, 0], [2, 0, 3, 1]] {
            let hp = h.permuted(&order).unwrap();
            let mean = hp.predict(&xq).unwrap();
            let (mu, var) = hp.predict_with_uncertainty(&xq).unwrap();
            for i in 0..xq.rows() {
                assert!(
                    (mean[i] - base_mean[i]).abs() < 1e-12,
                    "seed {seed} order {order:?}: DC-KRR mean drifted at row {i}"
                );
                assert!(
                    (mu[i] - base_mu[i]).abs() < 1e-12,
                    "seed {seed} order {order:?}: KBR fused mean drifted at row {i}"
                );
                assert!(
                    (var[i] - base_var[i]).abs() < 1e-12,
                    "seed {seed} order {order:?}: KBR fused variance drifted at row {i}"
                );
            }
        }
        // permuted() validates its input
        assert!(h.permuted(&[0, 1, 2]).is_err(), "wrong length");
        assert!(h.permuted(&[0, 1, 2, 2]).is_err(), "not a permutation");
    }
}

/// The multi-output twin of `fanin_is_invariant_under_shard_permutation`:
/// the packed (B, D) fan-in paths must be permutation-invariant too.
#[test]
fn multi_output_fanin_is_invariant_under_shard_permutation() {
    for seed in [13u64, 31] {
        let (x, y) = data(240, 5, seed);
        let ym = multi_targets(&y, 4);
        let (xq, _) = data(12, 5, 3000 + seed);
        let router = ShardRouter::bootstrap_multi(&x, &ym, serve_cfg(4, true)).unwrap();
        let h = router.handle();
        let base_mean = h.predict_multi(&xq).unwrap();
        let (base_mu, base_var) = h.predict_with_uncertainty_multi(&xq).unwrap();
        for order in [[3usize, 2, 1, 0], [1, 2, 3, 0]] {
            let hp = h.permuted(&order).unwrap();
            let mean = hp.predict_multi(&xq).unwrap();
            let (mu, var) = hp.predict_with_uncertainty_multi(&xq).unwrap();
            for i in 0..xq.rows() {
                for c in 0..4 {
                    assert!((mean[(i, c)] - base_mean[(i, c)]).abs() < 1e-12);
                    assert!((mu[(i, c)] - base_mu[(i, c)]).abs() < 1e-12);
                }
                assert!((var[i] - base_var[i]).abs() < 1e-12);
            }
        }
    }
}

/// K=4 shard parity at D=4: every output column of the sharded multi
/// prediction tracks the single-engine multi baseline within the same
/// DC-KRR envelope the D=1 test asserts.
#[test]
fn kshard_parity_at_d4_with_single_engine_baseline() {
    for seed in [1u64, 7] {
        let (x, y) = data(240, 6, seed);
        let ym = multi_targets(&y, 4);
        let (xq, _) = data(40, 6, 1000 + seed);
        let router = ShardRouter::bootstrap_multi(&x, &ym, serve_cfg(4, false)).unwrap();
        let single = mikrr::coordinator::engine::Engine::fit_multi(
            &x,
            &ym,
            &Kernel::poly(2, 1.0),
            0.5,
            router.space(),
            false,
        )
        .unwrap();
        let sharded = router.handle().predict_multi(&xq).unwrap();
        let baseline = single.predict_multi(&xq).unwrap();
        assert_eq!(sharded.shape(), (40, 4));
        for c in 0..4 {
            let dev = rmse(&sharded.col(c), &baseline.col(c));
            // column c's signal is (1 + 0.5 c)× the D=1 signal; the DC-KRR
            // deviation scales with it
            let scale = 1.0 + 0.5 * c as f64;
            assert!(dev < 0.30 * scale, "seed {seed} col {c}: sharded-vs-single rmse {dev}");
        }
    }
}

/// Rollback at D=4: a failing multi round on a snapshot_rollback shard
/// must restore the engine, leave the published epoch untouched, count
/// the rollback, and keep accepting valid multi rounds afterwards.
#[test]
fn failed_multi_round_rolls_back_and_recovers_at_d4() {
    let (x, y) = data(60, 5, 21);
    let ym = multi_targets(&y, 4);
    let mut cfg = serve_cfg(1, false);
    cfg.base.snapshot_rollback = true;
    let mut router = ShardRouter::bootstrap_multi(&x, &ym, cfg).unwrap();
    let h = router.handle();
    let (xq, _) = data(6, 5, 1021);
    let p0 = h.predict_multi(&xq).unwrap();

    // an out-of-range removal fails inside the engine round
    let (xc, yc) = data(2, 5, 22);
    let ycm = multi_targets(&yc, 4);
    let err = router.shard_mut(0).apply_update_multi(&xc, &ycm, &[500]);
    assert!(err.is_err(), "out-of-range removal must fail");
    assert_eq!(router.shard(0).counters().get("rollbacks"), 1);
    assert_eq!(h.epochs(), vec![0], "failed round must not publish");
    let p1 = h.predict_multi(&xq).unwrap();
    for (a, b) in p0.as_slice().iter().zip(p1.as_slice()) {
        assert_eq!(a, b, "published state changed after a rolled-back round");
    }

    // the shard keeps working: a valid multi round lands and publishes
    let out = router.shard_mut(0).apply_update_multi(&xc, &ycm, &[0, 1]).unwrap();
    assert_eq!(out.added, 2);
    assert_eq!(h.epochs(), vec![1]);
    assert_eq!(router.n_samples(), 60);

    // D=1 surface stays shimmed off on a D=4 shard
    assert!(router.shard_mut(0).apply_update(&xc, &yc, &[]).is_err());
    assert!(h.predict(&xq).is_err());
}

/// Multi-output events stream end to end at D=4: router fan-out, shard
/// batch assembly, and the coalesced multi predict answered as one packed
/// round.
#[test]
fn router_streams_multi_output_events_end_to_end() {
    let (x, y) = data(160, 6, 23);
    let ym = multi_targets(&y, 4);
    let mut router = ShardRouter::bootstrap_multi(&x, &ym, serve_cfg(2, true)).unwrap();
    let n0 = router.n_samples();

    let (xs, ys) = data(24, 6, 24);
    let ysm = multi_targets(&ys, 4);
    for i in 0..24 {
        router.ingest(StreamEvent::multi(xs.row(i).to_vec(), ysm.row(i), 0, i as u64));
    }
    let mut rounds = 0;
    loop {
        let report = router.update_round();
        if report.is_empty() {
            break;
        }
        assert!(report.errors.is_empty(), "{:?}", report.errors);
        rounds += 1;
        assert!(rounds < 100, "stream did not drain");
    }
    assert_eq!(router.n_samples(), n0 + 24);

    // a D=4 microbatch client coalesces multi requests into packed rounds
    let h = router.handle();
    let (xq, _) = data(8, 6, 1023);
    let direct = h.predict_multi(&xq).unwrap();
    let server = MicroBatchServer::spawn(h, 6, MicroBatchPolicy::default());
    let mut client = server.client();
    for i in 0..8 {
        let got = client.predict_multi(xq.row(i)).unwrap();
        assert_eq!(got.len(), 4);
        for c in 0..4 {
            assert!((got[c] - direct[(i, c)]).abs() < 1e-9);
        }
    }
    // scalar requests error cleanly against the D=4 deployment
    assert!(client.predict(xq.row(0)).is_err());
    let stats = server.shutdown();
    assert_eq!(stats.requests, 9);
}

/// ISSUE 7 regression — a permanently failing (poison) batch must land in
/// quarantine after exactly R attempts and never loop forever in the
/// router's drain. The poison rows are finite (1e200) so they pass the
/// event-boundary float validation, but they overflow the poly2 Gram and
/// hit the factorization's non-finite pivot guard on every attempt.
/// Meanwhile good traffic on the other shard keeps landing, readers stay
/// answered throughout, and the published state of the poisoned shard is
/// untouched (snapshot rollback restored the writer every time).
#[test]
fn poison_batch_quarantined_after_r_attempts_never_loops() {
    let (x, y) = data(80, 5, 31);
    let mut cfg = serve_cfg(2, false);
    cfg.base.snapshot_rollback = true;
    let mut router = ShardRouter::bootstrap(&x, &y, cfg).unwrap();
    let h = router.handle();
    let (xq, _) = data(6, 5, 1031);
    let p0 = h.predict(&xq).unwrap();

    let retry = RetryPolicy {
        max_attempts: 3,
        base_backoff: Duration::ZERO,
        max_backoff: Duration::ZERO,
        jitter: 0.0,
        seed: 7,
    };
    let sup_cfg = SupervisorConfig { retry, quarantine_after: 2, ..SupervisorConfig::default() };
    let mut sup = ShardSupervisor::new(sup_cfg, router.num_shards());

    // shard 0: poison; shard 1: a clean event that must still land
    router.shard_mut(0).push(StreamEvent::single(vec![1e200; 5], 0.0, 0, 0));
    let (xg, yg) = data(1, 5, 32);
    router.shard_mut(1).push(StreamEvent::single(xg.row(0).to_vec(), yg[0], 1, 1));

    // drain with a generous round cap: termination is the point under test
    let report = sup.drain(&mut router, 16);
    assert_eq!(report.added(), 1, "clean traffic landed despite the poison batch");
    assert_eq!(report.errors.len(), 1, "the poison batch failed exactly once at the end");

    // quarantine bookkeeping: R attempts spent, batch pulled off the queue
    assert_eq!(sup.counters().get("retries"), 2, "R−1 = 2 in-place retries");
    assert_eq!(sup.counters().get("batches_quarantined"), 1);
    assert_eq!(sup.counters().get("events_quarantined"), 1);
    let q = &sup.quarantined_batches()[0];
    assert_eq!(q.shard, 0);
    assert_eq!(q.attempts, 3);
    assert_eq!(q.events.len(), 1, "the poison event is retained as evidence");
    assert!(q.events[0].x.iter().all(|&v| v == 1e200));
    assert_eq!(router.shard(0).pending(), 0, "nothing left to requeue — no livelock");

    // one failed round < quarantine_after: degraded but still serving
    assert_eq!(router.shard(0).status(), ShardStatus::Degraded);
    assert_eq!(h.num_serving(), 2);

    // the poisoned shard never published: epoch still at bootstrap, and
    // reads stayed finite and answered throughout
    assert_eq!(router.shard(0).handle().epoch(), 0, "failed rounds never publish");
    let p1 = h.predict(&xq).unwrap();
    assert!(p0.iter().chain(&p1).all(|v| v.is_finite()));

    // afterwards the shard accepts clean traffic again and heals its marker
    let (xc, yc) = data(1, 5, 33);
    router.shard_mut(0).push(StreamEvent::single(xc.row(0).to_vec(), yc[0], 0, 2));
    let rep2 = sup.drain(&mut router, 4);
    assert!(rep2.errors.is_empty(), "{:?}", rep2.errors);
    assert_eq!(router.shard(0).status(), ShardStatus::Healthy);
    assert_eq!(router.shard(0).handle().epoch(), 1);
}

/// ISSUE 7 regression — non-finite payloads are rejected at the event
/// boundary with `rejected_nonfinite` counters, never reaching the retry
/// or quarantine machinery; and a shard pushed past `quarantine_after`
/// drops out of the read fan-in (K−1 serving) until its heal republishes.
#[test]
fn boundary_rejects_and_shard_quarantine_degrade_reads_to_k_minus_1() {
    let (x, y) = data(80, 5, 34);
    let mut cfg = serve_cfg(2, false);
    cfg.base.snapshot_rollback = true;
    let mut router = ShardRouter::bootstrap(&x, &y, cfg).unwrap();
    let h = router.handle();
    let (xq, _) = data(5, 5, 1034);

    let retry = RetryPolicy {
        max_attempts: 1,
        base_backoff: Duration::ZERO,
        max_backoff: Duration::ZERO,
        jitter: 0.0,
        seed: 9,
    };
    let sup_cfg = SupervisorConfig { retry, quarantine_after: 1, ..SupervisorConfig::default() };
    let mut sup = ShardSupervisor::new(sup_cfg, router.num_shards());

    // non-finite rows: boundary rejects, not quarantines
    router.shard_mut(0).push(StreamEvent::single(vec![f64::NAN; 5], 0.0, 0, 0));
    let inf_row = vec![0.0, f64::INFINITY, 0.0, 0.0, 0.0];
    router.shard_mut(1).push(StreamEvent::single(inf_row, 0.0, 1, 1));
    let rep = sup.drain(&mut router, 4);
    assert!(rep.errors.is_empty(), "{:?}", rep.errors);
    let nonfinite: u64 = (0..2).map(|i| router.shard(i).counters().get("rejected_nonfinite")).sum();
    assert_eq!(nonfinite, 2, "both bad rows counted at the boundary");
    assert_eq!(sup.counters().get("batches_quarantined"), 0);
    assert_eq!(sup.counters().get("retries"), 0);

    // now a poison batch with quarantine_after=1: the shard itself goes
    let expected_k1: Vec<f64> = h.shard(1).predict(&xq).unwrap();
    router.shard_mut(0).push(StreamEvent::single(vec![1e200; 5], 0.0, 0, 2));
    sup.supervise_round(&mut router);
    assert_eq!(router.shard(0).status(), ShardStatus::Quarantined);
    assert_eq!(h.num_serving(), 1);
    // the fan-in renormalizes over the surviving shard: K−1 serving equals
    // the healthy shard's own prediction exactly
    let fanin = h.predict(&xq).unwrap();
    for (a, b) in fanin.iter().zip(&expected_k1) {
        assert!((a - b).abs() < 1e-12, "K−1 fan-in must equal the lone healthy shard");
    }

    // next supervised round heals the quarantined shard (full refit from
    // retained stores) and it rejoins the average
    sup.supervise_round(&mut router);
    assert_eq!(router.shard(0).status(), ShardStatus::Healthy);
    assert_eq!(sup.counters().get("shards_recovered"), 1);
    assert_eq!(h.num_serving(), 2);
    let fanin2 = h.predict(&xq).unwrap();
    let s0 = h.shard(0).predict(&xq).unwrap();
    for i in 0..xq.rows() {
        let avg = 0.5 * (s0[i] + expected_k1[i]);
        assert!((fanin2[i] - avg).abs() < 1e-12, "healed shard rejoined the average");
    }
}
