//! AOT-runtime integration: load the `artifacts/` bundle, execute every
//! artifact through PJRT, and cross-check against the native f64 linalg
//! path (the hybrid dispatch contract).
//!
//! Requires `make artifacts` to have run; tests skip (pass with a notice)
//! when no artifact dir is present so `cargo test` works on a fresh
//! checkout.

use mikrr::kernels::Kernel;
use mikrr::linalg::solve::spd_inverse;
use mikrr::linalg::Mat;
use mikrr::runtime::pjrt::{PjrtRuntime, Tensor};
use mikrr::runtime::HybridExec;
use mikrr::testutil::{random_mat, random_spd};
use mikrr::util::prng::Rng;

fn runtime() -> Option<PjrtRuntime> {
    let dir = mikrr::runtime::artifact_dir()?;
    Some(PjrtRuntime::load_dir(&dir).expect("artifacts present but failed to load"))
}

macro_rules! need_runtime {
    () => {
        match runtime() {
            Some(rt) => rt,
            None => {
                eprintln!("skipping: no artifacts (run `make artifacts`)");
                return;
            }
        }
    };
}

/// A well-conditioned S^-1 shaped like the real maintained state.
fn canonical_state(j: usize, rng: &mut Rng) -> Mat {
    let s = random_spd(rng, j, 50.0);
    spd_inverse(&s).unwrap()
}

#[test]
fn all_manifest_artifacts_compiled() {
    let rt = need_runtime!();
    for name in [
        "phi_poly2",
        "woodbury_incdec",
        "krr_refresh",
        "gram_poly2",
        "gram_rbf",
        "kbr_update",
        "predict_batch",
        "kbr_predict",
    ] {
        assert!(rt.names().contains(&name), "missing artifact {name}");
    }
}

#[test]
fn woodbury_artifact_matches_native() {
    let rt = need_runtime!();
    let mut rng = Rng::new(1);
    let j = 253;
    let s_inv = canonical_state(j, &mut rng);
    let phi_h = random_mat(&mut rng, j, 6, 0.05);
    let signs = [1.0, 1.0, 1.0, 1.0, -1.0, -1.0];
    let out = rt
        .execute(
            "woodbury_incdec",
            &[
                Tensor::from_mat(&s_inv),
                Tensor::from_mat(&phi_h),
                Tensor::from_f64(vec![6], &signs),
            ],
        )
        .unwrap();
    let got = out[0].to_mat().unwrap();
    let want = mikrr::linalg::woodbury::incdec(&s_inv, &phi_h, &signs).unwrap();
    let diff = got.max_abs_diff(&want);
    assert!(diff < 5e-4, "AOT vs native diff {diff}"); // f32 artifact vs f64 native
}

#[test]
fn phi_poly2_artifact_matches_native() {
    let rt = need_runtime!();
    let mut rng = Rng::new(2);
    let x = random_mat(&mut rng, 6, 21, 0.5);
    let out = rt.execute("phi_poly2", &[Tensor::from_mat(&x)]).unwrap();
    let got = out[0].to_mat().unwrap();
    let table = Kernel::poly(2, 1.0).feature_table(21).unwrap();
    let want = table.map(&x);
    assert_eq!(got.shape(), (6, 253));
    // check k(x,y) identity instead of coordinate order (enumeration order
    // matches by construction, verify both):
    let diff = got.max_abs_diff(&want);
    assert!(diff < 1e-3, "feature map diff {diff}");
}

#[test]
fn gram_artifacts_match_native() {
    let rt = need_runtime!();
    let mut rng = Rng::new(3);
    let x = random_mat(&mut rng, 128, 21, 0.5);
    let y = random_mat(&mut rng, 128, 21, 0.5);
    for (name, kernel) in [
        ("gram_poly2", Kernel::poly(2, 1.0)),
        ("gram_rbf", Kernel::rbf_radius(50.0)),
    ] {
        let out = rt
            .execute(name, &[Tensor::from_mat(&x), Tensor::from_mat(&y)])
            .unwrap();
        let got = out[0].to_mat().unwrap();
        let want = kernel.gram(&x, &y);
        let diff = got.max_abs_diff(&want);
        assert!(diff < 1e-3, "{name} diff {diff}");
    }
}

#[test]
fn krr_refresh_artifact_matches_native() {
    let rt = need_runtime!();
    let mut rng = Rng::new(4);
    let j = 253;
    let s_inv = canonical_state(j, &mut rng);
    let psum = rng.gaussian_vec(j);
    let py = rng.gaussian_vec(j);
    let (sy, n) = (3.7, 500.0);
    let out = rt
        .execute(
            "krr_refresh",
            &[
                Tensor::from_mat(&s_inv),
                Tensor::from_f64(vec![j], &psum),
                Tensor::from_f64(vec![j], &py),
                Tensor::scalar(sy as f32),
                Tensor::scalar(n as f32),
            ],
        )
        .unwrap();
    let u_got = out[0].to_f64();
    let b_got = out[1].data[0] as f64;
    let ex = HybridExec::new(None);
    let (u_want, b_want) = ex.krr_refresh(&s_inv, &psum, &py, sy, n).unwrap();
    for (g, w) in u_got.iter().zip(&u_want) {
        assert!((g - w).abs() < 5e-4, "{g} vs {w}");
    }
    assert!((b_got - b_want).abs() < 5e-4);
}

#[test]
fn predict_batch_artifact() {
    let rt = need_runtime!();
    let mut rng = Rng::new(5);
    let u = rng.gaussian_vec(253);
    let b = 0.25;
    let phi_star = random_mat(&mut rng, 64, 253, 0.2);
    let out = rt
        .execute(
            "predict_batch",
            &[
                Tensor::from_f64(vec![253], &u),
                Tensor::scalar(b as f32),
                Tensor::from_mat(&phi_star),
            ],
        )
        .unwrap();
    let got = out[0].to_f64();
    let want = mikrr::linalg::gemm::gemv(&phi_star, &u).unwrap();
    for (g, w) in got.iter().zip(&want) {
        assert!((g - (w + b)).abs() < 2e-3, "{g} vs {}", w + b);
    }
}

#[test]
fn kbr_artifacts_run_and_are_consistent() {
    let rt = need_runtime!();
    let mut rng = Rng::new(6);
    let j = 253;
    let cov = canonical_state(j, &mut rng);
    let phi_h = random_mat(&mut rng, j, 6, 0.02);
    let signs = [1.0, 1.0, 1.0, 1.0, -1.0, -1.0];
    let phi_y = rng.gaussian_vec(j);
    let out = rt
        .execute(
            "kbr_update",
            &[
                Tensor::from_mat(&cov),
                Tensor::from_mat(&phi_h),
                Tensor::from_f64(vec![6], &signs),
                Tensor::from_f64(vec![j], &phi_y),
            ],
        )
        .unwrap();
    let cov_new = out[0].to_mat().unwrap();
    let mean_new = out[1].to_f64();
    assert_eq!(cov_new.shape(), (j, j));
    assert_eq!(mean_new.len(), j);
    // native reference (sigma_b2 = 0.01 baked into the artifact)
    let sb = 0.01f64;
    let mut scaled = phi_h.clone();
    scaled.scale(1.0 / sb.sqrt());
    let cov_want = mikrr::linalg::woodbury::incdec(&cov, &scaled, &signs).unwrap();
    let diff = cov_new.max_abs_diff(&cov_want);
    assert!(diff < 5e-3, "kbr_update cov diff {diff}");

    // predictive head consistency
    let phi_star = random_mat(&mut rng, 64, j, 0.1);
    let outp = rt
        .execute(
            "kbr_predict",
            &[
                Tensor::from_mat(&cov_new),
                Tensor::from_f64(vec![j], &mean_new),
                Tensor::from_mat(&phi_star),
            ],
        )
        .unwrap();
    let mu = outp[0].to_f64();
    let psi = outp[1].to_f64();
    assert_eq!(mu.len(), 64);
    assert!(psi.iter().all(|&v| v >= 0.009), "variance floor violated");
}

#[test]
fn hybrid_dispatch_uses_aot_for_canonical_shapes() {
    let Some(dir) = mikrr::runtime::artifact_dir() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let ex = HybridExec::new(Some(PjrtRuntime::load_dir(&dir).unwrap()));
    let mut rng = Rng::new(7);
    // canonical J=253, H=4 (padded to 6 internally)
    let s_inv = canonical_state(253, &mut rng);
    let phi_h = random_mat(&mut rng, 253, 4, 0.05);
    let signs = [1.0, 1.0, -1.0, -1.0];
    let got = ex.woodbury_incdec(&s_inv, &phi_h, &signs).unwrap();
    assert_eq!(ex.stats().0, 1, "expected AOT hit");
    let want = ex.woodbury_native(&s_inv, &phi_h, &signs).unwrap();
    assert!(got.max_abs_diff(&want) < 5e-4);
    // non-canonical J: must fall back
    let s_small = canonical_state(50, &mut rng);
    let phi_small = random_mat(&mut rng, 50, 2, 0.05);
    ex.woodbury_incdec(&s_small, &phi_small, &[1.0, -1.0]).unwrap();
    assert_eq!(ex.stats().1, 1, "expected native fallback");
}
