//! ISSUE 6 acceptance: multi-output parity and duplicate-input folding.
//!
//! * A D-output engine must equal D independent single-output engines to
//!   1e-10 through fit, a mixed +C/−R round, and an eviction-only round —
//!   on the empirical, intrinsic, sparse, and KBR paths.
//! * A stream with 50% repeated rows folded through the engine must equal
//!   the unfolded reference to 1e-10, with a strictly smaller store.

use mikrr::config::Space;
use mikrr::coordinator::engine::Engine;
use mikrr::data::synth;
use mikrr::kbr::{KbrHyper, KbrModel};
use mikrr::kernels::Kernel;
use mikrr::krr::empirical::EmpiricalKrr;
use mikrr::krr::empirical_sparse::SparseEmpiricalKrr;
use mikrr::krr::intrinsic::IntrinsicKrr;
use mikrr::krr::KrrModel;
use mikrr::linalg::{Mat, SparseMat};

const D: usize = 3;

/// Derive a (N, D) target matrix from one scalar label stream.
fn multi_targets(y: &[f64], d: usize) -> Mat {
    Mat::from_fn(y.len(), d, |i, j| {
        (1.0 + 0.5 * j as f64) * y[i] + 0.1 * (j as f64) * (y[i] * y[i] - 0.5)
    })
}

fn col_mat(y: &Mat, c: usize) -> Mat {
    Mat::from_vec(y.rows(), 1, y.col(c)).unwrap()
}

/// Drive fit + a mixed inc/dec round + an eviction-only round through any
/// trait engine, comparing the D-output engine against D singles.
fn assert_trait_engine_parity<F>(fit: F, dim: usize)
where
    F: Fn(&Mat, &Mat) -> Box<dyn KrrModel>,
{
    let base = synth::ecg_like(80, dim, 3);
    let ym = multi_targets(&base.y, D);
    let mut multi = fit(&base.x, &ym);
    let mut singles: Vec<Box<dyn KrrModel>> =
        (0..D).map(|c| fit(&base.x, &col_mat(&ym, c))).collect();

    let extra = synth::ecg_like(6, dim, 5);
    let ye = multi_targets(&extra.y, D);
    // round 1: mixed +6/−3
    let rem1 = [1usize, 7, 40];
    multi.inc_dec_multi(&extra.x, &ye, &rem1).unwrap();
    for (c, s) in singles.iter_mut().enumerate() {
        s.inc_dec_multi(&extra.x, &col_mat(&ye, c), &rem1).unwrap();
    }
    // round 2: eviction only
    let none_x = Mat::zeros(0, dim);
    let rem2 = [0usize, 4, 12, 60];
    multi.inc_dec_multi(&none_x, &Mat::zeros(0, D), &rem2).unwrap();
    for s in singles.iter_mut() {
        s.inc_dec_multi(&none_x, &Mat::zeros(0, 1), &rem2).unwrap();
    }

    assert_eq!(multi.n_samples(), singles[0].n_samples());
    assert_eq!(multi.n_outputs(), D);
    let q = synth::ecg_like(30, dim, 9);
    let pm = multi.predict_multi(&q.x).unwrap();
    assert_eq!(pm.shape(), (30, D));
    let tm = multi.predict_training_multi().unwrap();
    for (c, s) in singles.iter().enumerate() {
        let ps = s.predict(&q.x).unwrap();
        mikrr::testutil::assert_vec_close(&pm.col(c), &ps, 1e-10);
        let ts = s.predict_training().unwrap();
        mikrr::testutil::assert_vec_close(&tm.col(c), &ts, 1e-10);
    }
}

#[test]
fn intrinsic_multi_matches_independent_singles() {
    assert_trait_engine_parity(
        |x, y| Box::new(IntrinsicKrr::fit_multi(x, y, &Kernel::poly(2, 1.0), 0.5).unwrap()),
        8,
    );
}

#[test]
fn empirical_multi_matches_independent_singles() {
    assert_trait_engine_parity(
        |x, y| Box::new(EmpiricalKrr::fit_multi(x, y, &Kernel::rbf_radius(50.0), 0.7).unwrap()),
        8,
    );
}

#[test]
fn sparse_multi_matches_independent_singles() {
    let m = 5_000;
    let (xs, ys) = synth::drt_like_sparse(60, m, 0.01, 3);
    let ym = multi_targets(&ys, D);
    let poly2 = Kernel::poly(2, 1.0);
    let mut multi = SparseEmpiricalKrr::fit_multi(&xs, &ym, &poly2, 0.6).unwrap();
    let mut singles: Vec<SparseEmpiricalKrr> = (0..D)
        .map(|c| SparseEmpiricalKrr::fit_multi(&xs, &col_mat(&ym, c), &poly2, 0.6).unwrap())
        .collect();

    let (xe, ye_scalar) = synth::drt_like_sparse(4, m, 0.01, 7);
    let ye = multi_targets(&ye_scalar, D);
    let rem1 = [2usize, 30];
    multi.inc_dec_multi(&xe, &ye, &rem1).unwrap();
    for (c, s) in singles.iter_mut().enumerate() {
        s.inc_dec_multi(&xe, &col_mat(&ye, c), &rem1).unwrap();
    }
    let empty = SparseMat::from_rows(0, m, Vec::new()).unwrap();
    let rem2 = [0usize, 10, 45];
    multi.inc_dec_multi(&empty, &Mat::zeros(0, D), &rem2).unwrap();
    for s in singles.iter_mut() {
        s.inc_dec_multi(&empty, &Mat::zeros(0, 1), &rem2).unwrap();
    }

    assert_eq!(multi.n_samples(), singles[0].n_samples());
    assert_eq!(multi.n_outputs(), D);
    let (q, _) = synth::drt_like_sparse(20, m, 0.01, 11);
    let pm = multi.predict_multi(&q).unwrap();
    for (c, s) in singles.iter().enumerate() {
        let ps = s.predict(&q).unwrap();
        mikrr::testutil::assert_vec_close(&pm.col(c), &ps, 1e-10);
    }
}

#[test]
fn kbr_multi_matches_independent_singles() {
    let dim = 8;
    let base = synth::ecg_like(60, dim, 13);
    let ym = multi_targets(&base.y, D);
    let poly2 = Kernel::poly(2, 1.0);
    let hyper = KbrHyper::default();
    let mut multi = KbrModel::fit_multi(&base.x, &ym, &poly2, hyper).unwrap();
    let mut singles: Vec<KbrModel> = (0..D)
        .map(|c| KbrModel::fit_multi(&base.x, &col_mat(&ym, c), &poly2, hyper).unwrap())
        .collect();

    let extra = synth::ecg_like(5, dim, 17);
    let ye = multi_targets(&extra.y, D);
    let rem1 = [3usize, 20];
    multi.inc_dec_multi(&extra.x, &ye, &rem1).unwrap();
    for (c, s) in singles.iter_mut().enumerate() {
        s.inc_dec_multi(&extra.x, &col_mat(&ye, c), &rem1).unwrap();
    }
    let none_x = Mat::zeros(0, dim);
    let rem2 = [1usize, 8, 30];
    multi.inc_dec_multi(&none_x, &Mat::zeros(0, D), &rem2).unwrap();
    for s in singles.iter_mut() {
        s.inc_dec_multi(&none_x, &Mat::zeros(0, 1), &rem2).unwrap();
    }

    // posterior mean columns and the SHARED predictive variance
    let q = synth::ecg_like(16, dim, 19);
    let pm = multi.predict_multi(&q.x).unwrap();
    assert_eq!(pm.mean.shape(), (16, D));
    for (c, s) in singles.iter().enumerate() {
        let ps = s.predict(&q.x).unwrap();
        mikrr::testutil::assert_vec_close(&pm.mean.col(c), &ps.mean, 1e-10);
        // the precision is target-independent: every single-output twin
        // carries the exact same variance column
        mikrr::testutil::assert_vec_close(&pm.var, &ps.var, 1e-10);
    }
}

/// 50%-repeat stream: the folding engine must match the unfolded
/// reference to 1e-10 while keeping its store strictly smaller.
fn assert_folding_stream_parity(space: Space) {
    let dim = 8;
    let base = synth::ecg_like(70, dim, 23);
    let ym = multi_targets(&base.y, 2);
    let kernel = Kernel::poly(2, 1.0);
    let mut folding = Engine::fit_multi(&base.x, &ym, &kernel, 0.5, space, true).unwrap();
    folding.set_fold_eps(Some(0.0));
    let mut plain = Engine::fit_multi(&base.x, &ym, &kernel, 0.5, space, true).unwrap();

    let fresh = synth::ecg_like(40, dim, 29);
    let yf = multi_targets(&fresh.y, 2);
    for round in 0..8 {
        let mut xb = Mat::default();
        let mut yb = Mat::default();
        for k in 0..4 {
            if k % 2 == 0 {
                let i = round * 2 + k / 2;
                xb.push_row(fresh.x.row(i)).unwrap();
                yb.push_row(yf.row(i)).unwrap();
            } else {
                // exact repeat of a stored row, re-delivering its stored
                // (already multiplicity-averaged) target; drawn away from
                // the head so evictions never hit a weighted row
                let (xs, ys) = folding.training_view();
                let j = 30 + (round * 7 + k) % 35;
                let (xr, yr) = (xs.row(j).to_vec(), ys.row(j).to_vec());
                xb.push_row(&xr).unwrap();
                yb.push_row(&yr).unwrap();
            }
        }
        let rem = [round];
        folding.inc_dec_multi(&xb, &yb, &rem).unwrap();
        plain.inc_dec_multi(&xb, &yb, &rem).unwrap();
        assert_eq!(folding.last_round_folds(), 2, "round {round} should fold both repeats");
    }

    // folded store is strictly smaller; multiplicity mass is conserved
    assert!(folding.n_samples() < plain.n_samples());
    assert_eq!(plain.n_samples() - folding.n_samples(), 16);
    let mass: f64 = folding.multiplicities().iter().sum();
    assert!((mass - plain.n_samples() as f64).abs() < 1e-9);
    assert!(folding.multiplicities().iter().any(|&c| c > 1.0));

    // numerically equivalent posterior: predictions and uncertainty
    let q = synth::ecg_like(25, dim, 31);
    let pf = folding.predict_multi(&q.x).unwrap();
    let pp = plain.predict_multi(&q.x).unwrap();
    mikrr::testutil::assert_mat_close(&pf, &pp, 1e-10);
    let (mf, vf) = folding.predict_with_uncertainty_multi(&q.x).unwrap();
    let (mp, vp) = plain.predict_with_uncertainty_multi(&q.x).unwrap();
    mikrr::testutil::assert_mat_close(&mf, &mp, 1e-10);
    mikrr::testutil::assert_vec_close(&vf, &vp, 1e-10);
}

#[test]
fn folding_stream_matches_unfolded_intrinsic() {
    assert_folding_stream_parity(Space::Intrinsic);
}

#[test]
fn folding_stream_matches_unfolded_empirical() {
    assert_folding_stream_parity(Space::Empirical);
}

#[test]
fn near_duplicate_folding_respects_epsilon() {
    // ε-near repeats fold when within the tolerance and insert when not
    let dim = 6;
    let base = synth::ecg_like(40, dim, 37);
    let ym = multi_targets(&base.y, 1);
    let kernel = Kernel::poly(2, 1.0);
    let mut e = Engine::fit_multi(&base.x, &ym, &kernel, 0.5, Space::Intrinsic, false).unwrap();
    e.set_fold_eps(Some(1e-6));
    let n0 = e.n_samples();

    // within epsilon: folds
    let mut near = base.x.row(10).to_vec();
    near[0] += 1e-9;
    let xb = Mat::from_vec(1, dim, near).unwrap();
    let yb = Mat::from_vec(1, 1, vec![ym[(10, 0)]]).unwrap();
    e.inc_dec_multi(&xb, &yb, &[]).unwrap();
    assert_eq!(e.last_round_folds(), 1);
    assert_eq!(e.n_samples(), n0);

    // outside epsilon: inserts
    let mut far = base.x.row(10).to_vec();
    far[0] += 1e-3;
    let xb = Mat::from_vec(1, dim, far).unwrap();
    e.inc_dec_multi(&xb, &yb, &[]).unwrap();
    assert_eq!(e.last_round_folds(), 0);
    assert_eq!(e.n_samples(), n0 + 1);
}
