//! Chaos suite (ISSUE 7): seeded fault injection against the supervised
//! serving tier. Compiled and run only under `--features chaos`; the CI
//! lane sweeps a small seed matrix via the `CHAOS_SEED` env var.
//!
//! Every test drives the REAL state machine — boundary rejection, bounded
//! retry, poison-batch quarantine, shard quarantine with K−1 fan-in, and
//! probe-tripped self-heal — with faults scheduled by a deterministic
//! [`FaultPlan`], then checks the observed counters against the plan.

// The serving tests intentionally exercise the deprecated predict*
// shims alongside the unified query API.
#![allow(deprecated)]

#![cfg(feature = "chaos")]

use mikrr::data::synth;
use mikrr::health::{FaultKind, FaultPlan};
use mikrr::kernels::Kernel;
use mikrr::serve::{
    RetryPolicy, ServeConfig, ShardRouter, ShardStatus, ShardSupervisor, SupervisorConfig,
};
use mikrr::streaming::StreamEvent;
use mikrr::telemetry::SpanKind;
use std::time::Duration;

/// Seed for the randomized-plan test: overridable by the CI matrix.
fn chaos_seed(default: u64) -> u64 {
    std::env::var("CHAOS_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(default)
}

fn serve_cfg(shards: usize) -> ServeConfig {
    let mut cfg = ServeConfig::default_for(Kernel::poly(2, 1.0), shards);
    cfg.base.outlier = None;
    cfg.base.snapshot_rollback = true;
    cfg
}

fn router(shards: usize, seed: u64) -> ShardRouter {
    let d = synth::ecg_like(64, 5, seed);
    ShardRouter::bootstrap(&d.x, &d.y, serve_cfg(shards)).unwrap()
}

fn zero_backoff(max_attempts: u32, quarantine_after: u32) -> SupervisorConfig {
    SupervisorConfig {
        retry: RetryPolicy {
            max_attempts,
            base_backoff: Duration::ZERO,
            max_backoff: Duration::ZERO,
            jitter: 0.0,
            seed: 0,
        },
        quarantine_after,
        ..SupervisorConfig::default()
    }
}

/// One clean event per shard, distinct across rounds.
fn push_clean(r: &mut ShardRouter, round: u64) {
    let n = r.num_shards();
    let d = synth::ecg_like(n, 5, 9000 + round);
    for s in 0..n {
        r.shard_mut(s).push(StreamEvent::single(
            d.x.row(s).to_vec(),
            d.y[s],
            s,
            round * n as u64 + s as u64,
        ));
    }
}

/// NaN/Inf injections are rejected at the event boundary: the observed
/// `rejected_nonfinite` total equals the number of NaN/Inf faults in the
/// plan, and none of them consume retry budget or land in quarantine.
#[test]
fn nonfinite_injection_counts_match_plan() {
    let mut r = router(2, 51);
    let mut plan = FaultPlan::new(0);
    plan.push(0, 0, FaultKind::NanRow)
        .push(1, 0, FaultKind::InfRow)
        .push(0, 1, FaultKind::NanRow);
    let planned = plan.count_where(|f| {
        matches!(f.kind, FaultKind::NanRow | FaultKind::InfRow)
    }) as u64;
    let mut sup = ShardSupervisor::new(zero_backoff(3, 2), r.num_shards());
    sup.arm_faults(plan);
    for round in 0..3 {
        push_clean(&mut r, round);
        let rep = sup.supervise_round(&mut r);
        assert!(rep.errors.is_empty(), "round {round}: {:?}", rep.errors);
    }
    let nonfinite: u64 = (0..r.num_shards())
        .map(|i| r.shard(i).counters().get("rejected_nonfinite"))
        .sum();
    assert_eq!(nonfinite, planned, "boundary counter matches the injected plan");
    assert_eq!(sup.counters().get("faults_injected"), planned);
    assert_eq!(sup.counters().get("retries"), 0, "rejects never enter the retry loop");
    assert!(sup.quarantined_batches().is_empty());
    assert!(r.handle().statuses().iter().all(|s| *s == ShardStatus::Healthy));
}

/// A forced numerical failure is the canonical transient: one in-place
/// retry lands the same batch, nothing is quarantined, and the round's
/// update publishes as if the blip never happened.
#[test]
fn forced_numerical_failure_recovers_on_retry() {
    let mut r = router(2, 52);
    let mut plan = FaultPlan::new(0);
    plan.push(0, 0, FaultKind::ForcedNumerical);
    let mut sup = ShardSupervisor::new(zero_backoff(3, 2), r.num_shards());
    sup.arm_faults(plan);
    push_clean(&mut r, 0);
    let rep = sup.supervise_round(&mut r);
    assert!(rep.errors.is_empty(), "{:?}", rep.errors);
    assert_eq!(rep.added(), 2, "both shards' events landed");
    assert_eq!(sup.counters().get("retries"), 1, "exactly one retry consumed");
    assert_eq!(r.shard(0).counters().get("chaos_forced_failures"), 1);
    assert!(sup.quarantined_batches().is_empty());
    assert_eq!(r.shard(0).status(), ShardStatus::Healthy);
    assert_eq!(r.shard(0).handle().epoch(), 1, "the retried round published");
}

/// Poison rows pass boundary validation, fail numerically on every
/// attempt, and must end in batch quarantine with the full retry budget
/// spent — the quarantine count matches the injected fault count.
#[test]
fn poison_rows_end_in_quarantine_matching_plan() {
    let mut r = router(2, 53);
    let mut plan = FaultPlan::new(0);
    plan.push(0, 0, FaultKind::PoisonRow).push(1, 1, FaultKind::PoisonRow);
    let planned = plan.count_where(|f| f.kind == FaultKind::PoisonRow) as u64;
    let mut sup = ShardSupervisor::new(zero_backoff(3, 8), r.num_shards());
    sup.arm_faults(plan);
    for round in 0..3 {
        push_clean(&mut r, round);
        sup.supervise_round(&mut r);
    }
    sup.drain(&mut r, 8);
    assert_eq!(sup.counters().get("batches_quarantined"), planned);
    assert_eq!(sup.counters().get("events_quarantined"), planned);
    for q in sup.quarantined_batches() {
        assert_eq!(q.attempts, 3, "full retry budget spent on shard {}", q.shard);
        assert_eq!(q.events.len(), 1);
        assert!(q.events[0].x.iter().all(|v| v.is_finite()), "poison is finite");
    }
    let pending: usize = (0..2).map(|i| r.shard(i).pending()).sum();
    assert_eq!(pending, 0, "no poison left looping in any queue");
}

/// A wedged shard quarantines after `quarantine_after` consecutive failed
/// rounds; the router serves from the remaining K−1 shards the whole time
/// (renormalized fan-in, monotone epochs), then the shard heals and
/// rejoins.
#[test]
fn wedged_shard_serves_k_minus_1_then_heals() {
    let mut r = router(2, 54);
    let mut plan = FaultPlan::new(0);
    plan.push(0, 0, FaultKind::Wedge { rounds: 2 });
    let mut sup = ShardSupervisor::new(zero_backoff(1, 2), r.num_shards());
    sup.arm_faults(plan);
    let h = r.handle();
    let q = synth::ecg_like(6, 5, 9954);
    let lone = h.shard(1).predict(&q.x).unwrap();
    let mut last_epochs = h.epochs();

    // rounds 0 and 1: the wedge fails shard 0's flush both times
    for round in 0..2 {
        push_clean(&mut r, round);
        sup.supervise_round(&mut r);
        let now = h.epochs();
        for (e, le) in now.iter().zip(&last_epochs) {
            assert!(e >= le, "epochs must be monotone under injection");
        }
        last_epochs = now;
        // reads answered on every round, wedged or not
        assert_eq!(h.predict(&q.x).unwrap().len(), 6);
    }
    assert_eq!(r.shard(0).status(), ShardStatus::Quarantined);
    assert_eq!(h.num_serving(), 1);
    assert_eq!(sup.counters().get("shards_quarantined"), 1);
    // the quarantine froze a flight dump: the event trail into the
    // failure (flush attempts, rollbacks) ending at the quarantine marker
    assert_eq!(sup.flight_dumps().len(), 1, "one dump per quarantine");
    let dump = &sup.flight_dumps()[0];
    assert!(dump.label.contains("shard-0"), "{}", dump.label);
    assert!(dump.events.iter().any(|e| e.kind == SpanKind::Flush), "flush attempts held");
    assert_eq!(
        dump.events.last().map(|e| e.kind),
        Some(SpanKind::Quarantine),
        "trail ends at the quarantine marker"
    );
    assert!(dump.render_text().contains("quarantine"), "dump renders for post-mortems");
    // K−1 fan-in equals the lone healthy shard exactly (it saw 2 updates
    // since `lone` was read, so compare against its current snapshot)
    let lone_now = h.shard(1).predict(&q.x).unwrap();
    let fanin = h.predict(&q.x).unwrap();
    for (a, b) in fanin.iter().zip(&lone_now) {
        assert!((a - b).abs() < 1e-12, "K−1 fan-in == the healthy shard");
    }
    assert!(lone.iter().all(|v| v.is_finite()));

    // round 2: the supervisor heals the quarantined shard (refit +
    // republish) and it rejoins the average
    sup.supervise_round(&mut r);
    assert_eq!(r.shard(0).status(), ShardStatus::Healthy);
    assert_eq!(sup.counters().get("shards_recovered"), 1);
    assert_eq!(h.num_serving(), 2);
    let now = h.epochs();
    assert!(now[0] > last_epochs[0], "heal republishes");
    let s0 = h.shard(0).predict(&q.x).unwrap();
    let s1 = h.shard(1).predict(&q.x).unwrap();
    let fanin2 = h.predict(&q.x).unwrap();
    for i in 0..6 {
        assert!((fanin2[i] - 0.5 * (s0[i] + s1[i])).abs() < 1e-12);
    }
}

/// Silent inverse corruption: the update round still succeeds (and even
/// publishes the drifted state), only the residual probe sees it. After
/// `trip_after` consecutive breaches the supervisor self-heals — and the
/// healed writer re-converges to an uninjected control run within 1e-8.
#[test]
fn corrupt_inverse_trips_probe_and_reconverges() {
    let mut chaos = router(2, 55);
    let mut control = router(2, 55);
    let mut plan = FaultPlan::new(0);
    plan.push(0, 0, FaultKind::CorruptInverse { factor: 100.0 });
    let mut sup = ShardSupervisor::new(zero_backoff(3, 4), chaos.num_shards());
    sup.arm_faults(plan);
    let mut ctl = ShardSupervisor::new(zero_backoff(3, 4), control.num_shards());

    // round 0: corruption lands, then a clean update runs THROUGH the
    // corrupted inverse; round 1+: probes breach until trip_after (2)
    for round in 0..2 {
        push_clean(&mut chaos, round);
        push_clean(&mut control, round);
        sup.supervise_round(&mut chaos);
        ctl.supervise_round(&mut control);
    }
    assert!(sup.counters().get("probe_breaches") >= 2, "corruption was seen");
    assert_eq!(sup.counters().get("probe_trips"), 1, "trip_after breaches escalate");
    assert_eq!(sup.counters().get("heals"), 1, "the trip self-healed");
    assert_eq!(ctl.counters().get("probe_breaches"), 0, "control stays clean");

    // post-heal: every probe residual on the healed shard is tiny again
    let eng = chaos.shard(0).engine();
    let (mut g, mut rr) = (Vec::new(), Vec::new());
    for i in 0..eng.probe_dim() {
        let res = eng.probe_residual_into(i, &mut g, &mut rr).unwrap();
        assert!(res < 1e-8, "post-heal residual {res} at probe {i}");
    }
    // and the healed writer matches the uninjected control run to 1e-8
    // (heal the control too: both sides are then exact refactorizations of
    // the same retained training view, so the comparison isolates what the
    // corruption + heal changed rather than incremental-vs-retrain drift)
    control.shard_mut(0).heal().unwrap();
    let q = synth::ecg_like(8, 5, 9955);
    let healed = chaos.shard(0).engine().predict(&q.x).unwrap();
    let clean = control.shard(0).engine().predict(&q.x).unwrap();
    for (a, b) in healed.iter().zip(&clean) {
        assert!((a - b).abs() < 1e-8, "re-convergence: {a} vs {b}");
    }
}

/// The randomized plan is deterministic end to end: two identical runs
/// under the same `CHAOS_SEED` inject the same faults and leave byte-equal
/// counters, statuses, and epochs.
#[test]
fn randomized_plan_runs_deterministically() {
    let seed = chaos_seed(42);
    let run = || -> (String, String, Vec<u64>, Vec<ShardStatus>) {
        let mut r = router(2, 56);
        let plan = FaultPlan::random(seed, 2, 6, 8);
        let mut sup = ShardSupervisor::new(zero_backoff(2, 3), r.num_shards());
        sup.arm_faults(plan);
        for round in 0..6 {
            push_clean(&mut r, round);
            sup.supervise_round(&mut r);
        }
        sup.drain(&mut r, 8);
        let shard_counters = (0..r.num_shards())
            .map(|i| r.shard(i).counters().render())
            .collect::<Vec<_>>()
            .join(" | ");
        (sup.counters().render(), shard_counters, r.handle().epochs(), r.handle().statuses())
    };
    let a = run();
    let b = run();
    assert_eq!(a, b, "seed {seed}: chaos run must be bit-reproducible");
}
