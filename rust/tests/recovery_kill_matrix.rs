//! Kill-point recovery matrix (ISSUE 8): crash the durable serving fleet
//! at EVERY persist write/fsync/rename boundary and prove recovery
//! restores it — recovered KRR point predictions AND KBR posteriors match
//! an uninterrupted control run to 1e-8, for D=1 and D=4.
//!
//! Scenario per kill point: bootstrap a K=4 hash-placed fleet, make it
//! durable, warm it with a clean prefix of the stream, arm the kill point,
//! drive until it fires (dead-process semantics: from then on every
//! persist boundary fails), drop the router mid-flight, recover from disk,
//! re-feed exactly the events each shard's `high_seq` says were lost, and
//! compare against a control router that saw the whole stream with no
//! durability at all.
//!
//! The kill registry is process-global, so every test serializes on
//! `KILL_LOCK`; the CI lane additionally runs this file with
//! `--test-threads=1` across a seed matrix (`CHAOS_SEED`).

// Recovery parity intentionally checks the deprecated predict* shims
// against the unified query path.
#![allow(deprecated)]

#![cfg(feature = "chaos")]

use std::sync::Mutex;

use mikrr::data::synth;
use mikrr::health::KillPoint;
use mikrr::kernels::Kernel;
use mikrr::linalg::Mat;
use mikrr::persist::{kill, DurabilityConfig};
use mikrr::serve::{Placement, ServeConfig, ShardRouter, ShardStatus};
use mikrr::streaming::StreamEvent;
use mikrr::testutil::{assert_vec_close, ScratchDir};

/// Global serialization for the (process-global) kill registry.
static KILL_LOCK: Mutex<()> = Mutex::new(());

/// Seed for the synthetic workload: overridable by the CI matrix.
fn chaos_seed(default: u64) -> u64 {
    std::env::var("CHAOS_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(default)
}

/// Disarms the registry even when a scenario assertion panics, so one
/// failure cannot wedge every later test in the process.
struct Disarmed;
impl Drop for Disarmed {
    fn drop(&mut self) {
        kill::disarm();
    }
}

const TOL: f64 = 1e-8;
const K: usize = 4;
const N_BOOT: usize = 48;
const N_STREAM: usize = 40;
const WARM: usize = 6;

fn target_row(y: f64, d: usize) -> Vec<f64> {
    (0..d)
        .map(|j| match j {
            0 => y,
            1 => 0.5 * y,
            2 => y + 1.0,
            _ => -y,
        })
        .collect()
}

fn serve_cfg() -> ServeConfig {
    let mut cfg = ServeConfig::default_for(Kernel::poly(2, 1.0), K);
    cfg.placement = Placement::Hash;
    cfg.base.outlier = None;
    cfg.base.with_uncertainty = true;
    cfg.base.snapshot_rollback = true;
    cfg.base.batch.max_batch = 3;
    cfg
}

fn workload(d_outputs: usize, seed: u64) -> (Mat, Mat, Vec<StreamEvent>, Mat) {
    let boot = synth::ecg_like(N_BOOT, 5, seed);
    let stream = synth::ecg_like(N_STREAM, 5, seed + 1);
    let q = synth::ecg_like(8, 5, seed + 2);
    let mut ym = Mat::default();
    ym.resize_scratch(N_BOOT, d_outputs);
    for i in 0..N_BOOT {
        ym.row_mut(i).copy_from_slice(&target_row(boot.y[i], d_outputs));
    }
    let events: Vec<StreamEvent> = (0..N_STREAM)
        .map(|i| {
            StreamEvent::multi(
                stream.x.row(i).to_vec(),
                &target_row(stream.y[i], d_outputs),
                0,
                (i + 1) as u64,
            )
        })
        .collect();
    (boot.x, ym, events, q.x)
}

/// Ingest + flush until nothing is pending; every round must be clean.
fn drain_strict(r: &mut ShardRouter) {
    for _ in 0..128 {
        let report = r.update_round();
        assert!(report.errors.is_empty(), "{:?}", report.errors);
        let pending: usize = (0..r.num_shards()).map(|i| r.shard(i).pending()).sum();
        if pending == 0 {
            return;
        }
    }
    panic!("drain did not converge");
}

/// Fused mean + variance read, shape-independent: `(flat means, variances)`.
fn posterior(r: &ShardRouter, q: &Mat) -> (Vec<f64>, Vec<f64>) {
    let (mu, var) = r.handle().predict_with_uncertainty_multi(q).unwrap();
    (mu.as_slice().to_vec(), var)
}

fn kill_scenario(point: KillPoint, d_outputs: usize, seed: u64) {
    let dir = ScratchDir::new(&format!("killmat-{point:?}-d{d_outputs}"));
    let (bx, by, events, q) = workload(d_outputs, seed);

    // control: the whole stream, no durability, no crash
    let mut control = ShardRouter::bootstrap_multi(&bx, &by, serve_cfg()).unwrap();
    for ev in &events {
        control.ingest(ev.clone());
    }
    drain_strict(&mut control);
    let want_p = control.handle().predict_multi(&q).unwrap();
    let (want_mu, want_var) = posterior(&control, &q);

    // durable run, crashed at `point`
    let mut r = ShardRouter::bootstrap_multi(&bx, &by, serve_cfg()).unwrap();
    r.make_durable(
        dir.path(),
        DurabilityConfig { checkpoint_every: 2, keep_generations: 2 },
    )
    .unwrap();
    for ev in &events[..WARM] {
        r.ingest(ev.clone());
    }
    drain_strict(&mut r);

    kill::arm(point);
    let _guard = Disarmed;
    for ev in &events[WARM..] {
        r.ingest(ev.clone());
        let _ = r.update_round(); // errors are the point here
        if kill::fired() {
            break;
        }
    }
    assert!(kill::fired(), "{point:?} never fired — the scenario is vacuous");
    drop(r); // the crash: whatever was in memory is gone
    drop(_guard);

    let mut rec = ShardRouter::recover(dir.path()).unwrap();
    assert_eq!(rec.num_shards(), K);
    assert!(
        rec.handle().statuses().iter().all(|s| *s == ShardStatus::Healthy),
        "{point:?}: recovered inverses must probe healthy"
    );
    if point == KillPoint::WalAppendTorn {
        assert!(
            rec.durability_counters().get("torn_tails_truncated") >= 1,
            "{point:?} must leave a torn tail for recovery to truncate"
        );
    }
    // exactly-once re-feed: only events above each shard's recovered
    // high-water mark, routed by the same content hash
    let seqs = rec.high_seqs();
    for ev in &events {
        let s = rec
            .placement()
            .shard_of(&ev.x, K)
            .expect("hash placement is content-addressed");
        if ev.seq > seqs[s] {
            rec.ingest(ev.clone());
        }
    }
    drain_strict(&mut rec);

    let got_p = rec.handle().predict_multi(&q).unwrap();
    assert_vec_close(got_p.as_slice(), want_p.as_slice(), TOL);
    let (got_mu, got_var) = posterior(&rec, &q);
    assert_vec_close(&got_mu, &want_mu, TOL);
    assert_vec_close(&got_var, &want_var, TOL);
}

#[test]
fn kill_point_matrix_d1() {
    let _g = KILL_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let seed = chaos_seed(42);
    for point in KillPoint::ALL {
        kill_scenario(point, 1, seed);
    }
}

#[test]
fn kill_point_matrix_d4() {
    let _g = KILL_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let seed = chaos_seed(42);
    for point in KillPoint::ALL {
        kill_scenario(point, 4, seed);
    }
}

/// A crash that corrupts the newest snapshot on top of the kill: recovery
/// falls back a generation, replays the longer WAL suffix, and still
/// matches the control run.
#[test]
fn kill_plus_corrupted_newest_snapshot_falls_back() {
    let _g = KILL_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let seed = chaos_seed(42);
    let dir = ScratchDir::new("killmat-fallback");
    let (bx, by, events, q) = workload(1, seed + 100);

    let mut control = ShardRouter::bootstrap_multi(&bx, &by, serve_cfg()).unwrap();
    for ev in &events {
        control.ingest(ev.clone());
    }
    drain_strict(&mut control);
    let want_p = control.handle().predict_multi(&q).unwrap();

    let mut r = ShardRouter::bootstrap_multi(&bx, &by, serve_cfg()).unwrap();
    r.make_durable(
        dir.path(),
        DurabilityConfig { checkpoint_every: 2, keep_generations: 2 },
    )
    .unwrap();
    // a longer warm phase than the matrix: ≥1 shard must have checkpointed
    // (pigeonhole: 20 events over 4 shards → some shard ran ≥2 rounds)
    for ev in &events[..20] {
        r.ingest(ev.clone());
    }
    drain_strict(&mut r);
    kill::arm(KillPoint::WalFsync);
    let _guard = Disarmed;
    for ev in &events[20..] {
        r.ingest(ev.clone());
        let _ = r.update_round();
        if kill::fired() {
            break;
        }
    }
    assert!(kill::fired());
    drop(r);
    drop(_guard);

    // bit-flip every shard's NEWEST snapshot: recovery must fall back and
    // recover the round coverage from the WAL instead
    use mikrr::persist::snapshot::{list_generations, snapshot_path};
    let mut flipped = 0u64;
    for shard in 0..K {
        let gens = list_generations(dir.path(), shard).unwrap();
        let newest = *gens.last().unwrap();
        if gens.len() < 2 {
            continue; // single generation: corrupting it would lose the shard
        }
        let path = snapshot_path(dir.path(), shard, newest);
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x10;
        std::fs::write(&path, &bytes).unwrap();
        flipped += 1;
    }
    assert!(flipped > 0, "warm phase must have produced rotated generations");

    let mut rec = ShardRouter::recover(dir.path()).unwrap();
    assert_eq!(rec.durability_counters().get("snapshot_fallbacks"), flipped);
    let seqs = rec.high_seqs();
    for ev in &events {
        let s = rec.placement().shard_of(&ev.x, K).unwrap();
        if ev.seq > seqs[s] {
            rec.ingest(ev.clone());
        }
    }
    drain_strict(&mut rec);
    let got_p = rec.handle().predict_multi(&q).unwrap();
    assert_vec_close(got_p.as_slice(), want_p.as_slice(), TOL);
}
