//! Tier-1 durability coverage (no chaos feature needed): snapshot codec
//! round trips, corruption rejection, WAL round trips, and crash-free
//! durable-router recovery matching live predictions. The kill-point
//! crash matrix builds on these in `rust/tests/recovery_kill_matrix.rs`
//! (`--features chaos`).

// The serving tests intentionally exercise the deprecated predict*
// shims alongside the unified query API.
#![allow(deprecated)]

use mikrr::config::Space;
use mikrr::coordinator::engine::Engine;
use mikrr::data::synth;
use mikrr::kernels::Kernel;
use mikrr::linalg::Mat;
use mikrr::persist::snapshot::{list_generations, snapshot_path};
use mikrr::persist::wal::{read_records, wal_path, Wal};
use mikrr::persist::{DurabilityConfig, EngineState, WalRecord};
use mikrr::serve::{Placement, ServeConfig, ShardRouter};
use mikrr::streaming::StreamEvent;
use mikrr::testutil::{assert_vec_close, ScratchDir};

fn bits(m: &Mat) -> Vec<u64> {
    m.as_slice().iter().map(|v| v.to_bits()).collect()
}

#[test]
fn snapshot_codec_round_trips_bit_exact_d1_with_folds() {
    let d = synth::ecg_like(28, 4, 101);
    let mut e =
        Engine::fit(&d.x, &d.y, &Kernel::poly(2, 1.0), 0.5, Space::Intrinsic, false).unwrap();
    e.set_fold_eps(Some(1e-9));
    // insert an exact duplicate of row 0: folds into multiplicity 2
    let dup = Mat::from_vec(1, 4, d.x.row(0).to_vec()).unwrap();
    e.inc_dec(&dup, &[d.y[0] + 0.25], &[]).unwrap();
    assert!(
        (e.multiplicities()[0] - 2.0).abs() < 1e-12,
        "duplicate folded: {:?}",
        &e.multiplicities()[..2]
    );

    let state = EngineState::capture(&e, 3, 5, 7);
    let got = EngineState::decode(&state.encode()).unwrap();
    assert_eq!((got.generation, got.epoch, got.high_seq), (3, 5, 7));
    assert_eq!(got.space, Space::Intrinsic);
    assert!(!got.with_uncertainty);
    assert_eq!(got.ridge.to_bits(), 0.5f64.to_bits());
    assert_eq!(got.fold_eps.map(f64::to_bits), Some(1e-9f64.to_bits()));
    assert_eq!(got.kernel, Kernel::poly(2, 1.0));
    // the training view and multiplicities survive BIT-exactly
    assert_eq!(bits(&got.x), bits(&state.x));
    assert_eq!(bits(&got.y), bits(&state.y));
    assert_eq!(
        got.mult.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
        state.mult.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
    );

    let rebuilt = got.rebuild().unwrap();
    assert_eq!(rebuilt.n_samples(), e.n_samples());
    assert!((rebuilt.multiplicities()[0] - 2.0).abs() < 1e-12);
    let q = synth::ecg_like(6, 4, 102);
    assert_vec_close(&rebuilt.predict(&q.x).unwrap(), &e.predict(&q.x).unwrap(), 1e-9);
}

#[test]
fn snapshot_codec_round_trips_d4_with_uncertainty() {
    let d = synth::ecg_like(30, 5, 103);
    let mut ym = Mat::default();
    ym.resize_scratch(30, 4);
    for i in 0..30 {
        let y = d.y[i];
        ym.row_mut(i).copy_from_slice(&[y, 0.5 * y, y + 1.0, -y]);
    }
    let e = Engine::fit_multi(
        &d.x,
        &ym,
        &Kernel::Rbf { gamma: 0.05 },
        0.7,
        Space::Empirical,
        true,
    )
    .unwrap();
    let state = EngineState::capture(&e, 1, 0, 0);
    let got = EngineState::decode(&state.encode()).unwrap();
    assert!(got.with_uncertainty);
    assert_eq!(got.kernel, Kernel::Rbf { gamma: 0.05 });
    assert_eq!((got.y.rows(), got.y.cols()), (30, 4));
    assert_eq!(bits(&got.x), bits(&state.x));
    assert_eq!(bits(&got.y), bits(&state.y));

    let rebuilt = got.rebuild().unwrap();
    let q = synth::ecg_like(5, 5, 104);
    let pm = rebuilt.predict_multi(&q.x).unwrap();
    let pe = e.predict_multi(&q.x).unwrap();
    assert_vec_close(pm.as_slice(), pe.as_slice(), 1e-9);
    let (mu_r, var_r) = rebuilt.predict_with_uncertainty_multi(&q.x).unwrap();
    let (mu_e, var_e) = e.predict_with_uncertainty_multi(&q.x).unwrap();
    assert_vec_close(mu_r.as_slice(), mu_e.as_slice(), 1e-9);
    assert_vec_close(&var_r, &var_e, 1e-9);
}

#[test]
fn snapshot_codec_rejects_truncation_and_bit_flips() {
    let d = synth::ecg_like(20, 3, 105);
    let e =
        Engine::fit(&d.x, &d.y, &Kernel::Linear, 0.4, Space::Intrinsic, false).unwrap();
    let bytes = EngineState::capture(&e, 2, 1, 1).encode();
    assert!(EngineState::decode(&bytes).is_ok());
    // every truncation point fails loudly (sampled stride + the last byte)
    let mut cut = 0;
    while cut < bytes.len() {
        assert!(
            EngineState::decode(&bytes[..cut]).is_err(),
            "truncation to {cut} of {} must not decode",
            bytes.len()
        );
        cut += 17;
    }
    assert!(EngineState::decode(&bytes[..bytes.len() - 1]).is_err());
    // any flipped bit fails loudly: magic/version by direct check, every
    // section byte by its CRC
    let mut at = 0;
    while at < bytes.len() {
        let mut bad = bytes.clone();
        bad[at] ^= 0x40;
        assert!(EngineState::decode(&bad).is_err(), "bit flip at {at} must not decode");
        at += 13;
    }
    // trailing garbage after SEC_END is rejected too
    let mut long = bytes.clone();
    long.push(0);
    assert!(EngineState::decode(&long).is_err());
}

#[test]
fn wal_round_trips_multi_output_batches() {
    let dir = ScratchDir::new("persist-wal-rt");
    let mut wal = Wal::create(dir.path(), 3, 1).unwrap();
    let mut scratch = Vec::new();
    let recs = vec![
        WalRecord::Batch {
            seq: 1,
            events: vec![
                StreamEvent::multi(vec![0.25, -1.5], &[1.0, -0.0, 1e-300], 9, 11),
                StreamEvent::single(vec![2.0, 4.0], 0.125, 0, 12),
            ],
        },
        WalRecord::Evict { seq: 2 },
        WalRecord::Heal { seq: 3 },
    ];
    for r in &recs {
        wal.append(r, &mut scratch).unwrap();
    }
    drop(wal);
    let (got, torn) = read_records(&wal_path(dir.path(), 3, 1)).unwrap();
    assert!(!torn);
    assert_eq!(got.len(), 3);
    assert_eq!(got.iter().map(WalRecord::seq).collect::<Vec<_>>(), vec![1, 2, 3]);
    match (&got[0], &recs[0]) {
        (
            WalRecord::Batch { events: ge, .. },
            WalRecord::Batch { events: we, .. },
        ) => {
            assert_eq!(ge.len(), we.len());
            for (g, w) in ge.iter().zip(we) {
                assert_eq!(g.seq, w.seq);
                assert_eq!(g.source_id, w.source_id);
                assert_eq!(
                    g.x.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    w.x.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
                );
                assert_eq!(g.y.to_bits(), w.y.to_bits());
                assert_eq!(
                    g.y_tail.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    w.y_tail.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
                );
            }
        }
        other => panic!("batch record did not round trip: {other:?}"),
    }
    // reopening the intact segment reports no torn tail
    let (reopened, replayed, torn) = Wal::open(dir.path(), 3, 1).unwrap();
    assert!(!torn);
    assert_eq!(replayed.len(), 3);
    drop(reopened);
}

fn drain(r: &mut ShardRouter) {
    for _ in 0..64 {
        let report = r.update_round();
        assert!(report.errors.is_empty(), "{:?}", report.errors);
        if r.num_shards() == 0 {
            break;
        }
        let pending: usize = (0..r.num_shards()).map(|i| r.shard(i).pending()).sum();
        if pending == 0 {
            break;
        }
    }
    let pending: usize = (0..r.num_shards()).map(|i| r.shard(i).pending()).sum();
    assert_eq!(pending, 0, "drain left events pending");
}

/// Crash-free end-to-end: durable K=4 fleet with checkpoints, recovered
/// predictions (point + posterior) match the live router at 1e-8.
#[test]
fn durable_router_recovery_matches_live_predictions() {
    let dir = ScratchDir::new("persist-e2e");
    let d = synth::ecg_like(48, 5, 106);
    let extra = synth::ecg_like(40, 5, 107);
    let q = synth::ecg_like(8, 5, 108);
    let mut cfg = ServeConfig::default_for(Kernel::poly(2, 1.0), 4);
    cfg.placement = Placement::Hash;
    cfg.base.outlier = None;
    cfg.base.with_uncertainty = true;
    cfg.base.snapshot_rollback = true;
    cfg.base.batch.max_batch = 3;
    let mut r = ShardRouter::bootstrap(&d.x, &d.y, cfg).unwrap();
    r.make_durable(
        dir.path(),
        DurabilityConfig { checkpoint_every: 2, keep_generations: 2 },
    )
    .unwrap();
    for i in 0..40 {
        r.ingest(StreamEvent::single(
            extra.x.row(i).to_vec(),
            extra.y[i],
            0,
            (i + 1) as u64,
        ));
    }
    drain(&mut r);
    // exercise the non-batch record kinds on the live path too
    let evict_report = r.evict_outliers();
    assert!(evict_report.errors.is_empty(), "{:?}", evict_report.errors);
    r.shard_mut(0).heal().unwrap();

    let h = r.handle();
    let live_p = h.predict(&q.x).unwrap();
    let (live_mu, live_var) = h.predict_with_uncertainty(&q.x).unwrap();
    let live_seqs = r.high_seqs();
    assert_eq!(*live_seqs.iter().max().unwrap(), 40);
    let live_dc = r.durability_counters();
    assert!(live_dc.get("snapshots_written") >= 4, "{live_dc:?}");
    assert!(live_dc.get("wal_records_appended") > 0, "{live_dc:?}");
    drop(r);

    let rec = ShardRouter::recover(dir.path()).unwrap();
    assert_eq!(rec.num_shards(), 4);
    assert_eq!(rec.placement(), Placement::Hash);
    assert_eq!(rec.high_seqs(), live_seqs);
    let rh = rec.handle();
    assert!(
        rh.statuses().iter().all(|s| *s == mikrr::serve::ShardStatus::Healthy),
        "recovered inverses must probe healthy: {:?}",
        rh.statuses()
    );
    assert_vec_close(&rh.predict(&q.x).unwrap(), &live_p, 1e-8);
    let (mu, var) = rh.predict_with_uncertainty(&q.x).unwrap();
    assert_vec_close(&mu, &live_mu, 1e-8);
    assert_vec_close(&var, &live_var, 1e-8);
    // the durability counters surface through the standard iter() protocol
    let dc = rec.durability_counters();
    let names: Vec<&str> = dc.iter().map(|(n, _)| n).collect();
    assert!(names.contains(&"snapshots_written"), "{names:?}");
    assert_eq!(dc.get("snapshot_fallbacks"), 0);
    assert_eq!(dc.get("torn_tails_truncated"), 0);
}

/// With checkpoints disabled (huge cadence) every applied round lives only
/// in WAL segment 1, so recovery must replay exactly what was appended —
/// including multi-output batches, an eviction round, and a heal.
#[test]
fn recovery_replays_the_full_wal_suffix_d4() {
    let dir = ScratchDir::new("persist-replay-all");
    let d = synth::ecg_like(48, 5, 109);
    let extra = synth::ecg_like(20, 5, 110);
    let q = synth::ecg_like(6, 5, 111);
    let row4 = |y: f64| [y, 0.5 * y, y + 1.0, -y];
    let mut ym = Mat::default();
    ym.resize_scratch(48, 4);
    for i in 0..48 {
        ym.row_mut(i).copy_from_slice(&row4(d.y[i]));
    }
    let mut cfg = ServeConfig::default_for(Kernel::poly(2, 1.0), 2);
    cfg.placement = Placement::Hash;
    cfg.base.outlier = None;
    cfg.base.with_uncertainty = true;
    cfg.base.snapshot_rollback = true;
    cfg.base.batch.max_batch = 3;
    let mut r = ShardRouter::bootstrap_multi(&d.x, &ym, cfg).unwrap();
    r.make_durable(
        dir.path(),
        DurabilityConfig { checkpoint_every: 1_000, keep_generations: 2 },
    )
    .unwrap();
    for i in 0..20 {
        r.ingest(StreamEvent::multi(
            extra.x.row(i).to_vec(),
            &row4(extra.y[i]),
            0,
            (i + 1) as u64,
        ));
    }
    drain(&mut r);
    let evict_report = r.evict_outliers();
    assert!(evict_report.errors.is_empty(), "{:?}", evict_report.errors);
    r.shard_mut(1).heal().unwrap();

    let h = r.handle();
    let live_pm = h.predict_multi(&q.x).unwrap();
    let (live_mu, live_var) = h.predict_with_uncertainty_multi(&q.x).unwrap();
    let live_seqs = r.high_seqs();
    let appended = r.durability_counters().get("wal_records_appended");
    assert!(appended > 0);
    drop(r);

    let rec = ShardRouter::recover(dir.path()).unwrap();
    assert_eq!(rec.high_seqs(), live_seqs);
    let dc = rec.durability_counters();
    assert_eq!(
        dc.get("wal_records_replayed"),
        appended,
        "no checkpoints → every appended record replays: {dc:?}"
    );
    assert_eq!(dc.get("wal_replay_skipped"), 0);
    let rh = rec.handle();
    let pm = rh.predict_multi(&q.x).unwrap();
    assert_vec_close(pm.as_slice(), live_pm.as_slice(), 1e-8);
    let (mu, var) = rh.predict_with_uncertainty_multi(&q.x).unwrap();
    assert_vec_close(mu.as_slice(), live_mu.as_slice(), 1e-8);
    assert_vec_close(&var, &live_var, 1e-8);
}

/// Corrupting the newest on-disk snapshot of one shard: recovery falls
/// back a generation, replays the longer WAL suffix, counts the fallback,
/// and still matches the live run.
#[test]
fn corrupted_newest_snapshot_falls_back_a_generation() {
    let dir = ScratchDir::new("persist-fallback");
    let d = synth::ecg_like(48, 5, 112);
    let extra = synth::ecg_like(6, 5, 113);
    let q = synth::ecg_like(6, 5, 114);
    let mut cfg = ServeConfig::default_for(Kernel::poly(2, 1.0), 2);
    // round-robin: both shards deterministically see 3 of the 6 arrivals,
    // so shard 0 is guaranteed to have rotated generations
    cfg.placement = Placement::RoundRobin;
    cfg.base.outlier = None;
    cfg.base.snapshot_rollback = true;
    cfg.base.batch.max_batch = 2;
    let mut r = ShardRouter::bootstrap(&d.x, &d.y, cfg).unwrap();
    r.make_durable(
        dir.path(),
        DurabilityConfig { checkpoint_every: 1, keep_generations: 3 },
    )
    .unwrap();
    for i in 0..6 {
        r.ingest(StreamEvent::single(
            extra.x.row(i).to_vec(),
            extra.y[i],
            0,
            (i + 1) as u64,
        ));
    }
    drain(&mut r);
    let live_p = r.handle().predict(&q.x).unwrap();
    let live_seqs = r.high_seqs();
    drop(r);

    // flip one byte in the NEWEST snapshot generation of shard 0
    let newest = *list_generations(dir.path(), 0).unwrap().last().unwrap();
    assert!(newest >= 2, "checkpoint_every=1 must have rotated generations");
    let path = snapshot_path(dir.path(), 0, newest);
    let mut bytes = std::fs::read(&path).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x01;
    std::fs::write(&path, &bytes).unwrap();

    let rec = ShardRouter::recover(dir.path()).unwrap();
    let dc = rec.durability_counters();
    assert_eq!(dc.get("snapshot_fallbacks"), 1, "{dc:?}");
    assert_eq!(rec.high_seqs(), live_seqs);
    assert_vec_close(&rec.handle().predict(&q.x).unwrap(), &live_p, 1e-8);
    // the corrupt file was quarantined aside, not deleted
    assert!(std::fs::metadata(path.with_extension("snap.corrupt")).is_ok());
}
