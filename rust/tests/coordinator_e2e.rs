//! End-to-end streaming tests: sensor fleet -> sink -> batcher ->
//! coordinator, with outlier injection exercising the decremental path,
//! concurrent prediction traffic, and failure handling.

use mikrr::coordinator::{Coordinator, CoordinatorConfig};
use mikrr::data::synth;
use mikrr::kernels::Kernel;
use mikrr::krr::classification_accuracy;
use mikrr::streaming::batcher::BatchPolicy;
use mikrr::streaming::outlier::OutlierConfig;
use mikrr::streaming::sink::SinkNode;
use mikrr::streaming::source::{SensorNode, SourceConfig};
use std::time::Duration;

fn coordinator_cfg(batch: usize) -> CoordinatorConfig {
    CoordinatorConfig {
        kernel: Kernel::poly(2, 1.0),
        ridge: 0.5,
        space: None,
        batch: BatchPolicy { max_batch: batch, max_wait: Duration::from_millis(40) },
        outlier: Some(OutlierConfig { z_threshold: 6.0, max_removals: 2 }),
        with_uncertainty: false,
        snapshot_rollback: false,
        fold_eps: None,
    }
}

#[test]
fn full_pipeline_with_outlier_injection() {
    let dim = 10;
    let base = synth::ecg_like(600, dim, 1);
    let mut coordinator = Coordinator::bootstrap(&base.x, &base.y, coordinator_cfg(4)).unwrap();

    let mut sink = SinkNode::new(64);
    let mut handles = Vec::new();
    for sid in 0..3 {
        let shard = synth::ecg_like(40, dim, 100 + sid as u64);
        let cfg = SourceConfig {
            source_id: sid,
            outlier_rate: 0.1, // 10% corrupted samples
            delay: None,
            seed: 50 + sid as u64,
        };
        handles.push(SensorNode::new(shard, cfg).spawn(sink.sender()));
    }
    let outcomes = coordinator.run(&mut sink, usize::MAX).unwrap();
    for h in handles {
        h.join().unwrap();
    }
    let added: usize = outcomes.iter().map(|o| o.added).sum();
    assert_eq!(added, 120, "all streamed samples processed");
    assert_eq!(sink.pooled(), 120);
    // model stayed accurate despite corrupted arrivals (outlier removal
    // keeps pruning the worst offenders)
    let test = synth::ecg_like(500, dim, 999);
    let pred = coordinator.handle().predict(&test.x).unwrap();
    let acc = classification_accuracy(&pred, &test.y);
    assert!(acc > 0.85, "post-stream accuracy {acc}");
}

#[test]
fn prediction_traffic_during_updates() {
    let dim = 8;
    let base = synth::ecg_like(300, dim, 2);
    let mut coordinator = Coordinator::bootstrap(&base.x, &base.y, coordinator_cfg(4)).unwrap();
    let handle = coordinator.handle();

    // reader thread hammers predictions while the coordinator updates
    let stop = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false));
    let stop_r = std::sync::Arc::clone(&stop);
    let reader = std::thread::spawn(move || {
        let queries = synth::ecg_like(16, dim, 3);
        let mut served = 0usize;
        while !stop_r.load(std::sync::atomic::Ordering::Relaxed) {
            let p = handle.predict(&queries.x).unwrap();
            assert!(p.iter().all(|v| v.is_finite()));
            served += 1;
        }
        served
    });

    let mut sink = SinkNode::new(32);
    let shard = synth::ecg_like(60, dim, 4);
    let src = SensorNode::new(shard, SourceConfig::default()).spawn(sink.sender());
    coordinator.run(&mut sink, usize::MAX).unwrap();
    src.join().unwrap();
    stop.store(true, std::sync::atomic::Ordering::Relaxed);
    let served = reader.join().unwrap();
    assert!(served > 0, "reader made progress during updates");
}

#[test]
fn uncertainty_pipeline_end_to_end() {
    let dim = 8;
    let base = synth::ecg_like(250, dim, 5);
    let mut cfg = coordinator_cfg(4);
    cfg.with_uncertainty = true;
    let mut coordinator = Coordinator::bootstrap(&base.x, &base.y, cfg).unwrap();

    let mut sink = SinkNode::new(32);
    let shard = synth::ecg_like(24, dim, 6);
    let src = SensorNode::new(shard, SourceConfig::default()).spawn(sink.sender());
    coordinator.run(&mut sink, usize::MAX).unwrap();
    src.join().unwrap();

    let test = synth::ecg_like(20, dim, 7);
    let (mu, var) = coordinator
        .handle()
        .predict_with_uncertainty(&test.x)
        .unwrap();
    assert_eq!(mu.len(), 20);
    assert!(var.iter().all(|&v| v > 0.0));
    // KBR variance must be >= the noise floor
    assert!(var.iter().all(|&v| v >= 0.0099));
}

#[test]
fn counters_and_latency_are_recorded() {
    let dim = 6;
    let base = synth::ecg_like(200, dim, 8);
    let mut coordinator = Coordinator::bootstrap(&base.x, &base.y, coordinator_cfg(6)).unwrap();
    let mut sink = SinkNode::new(32);
    let shard = synth::ecg_like(30, dim, 9);
    let src = SensorNode::new(shard, SourceConfig::default()).spawn(sink.sender());
    let outcomes = coordinator.run(&mut sink, usize::MAX).unwrap();
    src.join().unwrap();
    assert_eq!(coordinator.counters.get("rounds") as usize, outcomes.len());
    assert_eq!(coordinator.counters.get("added"), 30);
    assert_eq!(coordinator.update_latency.count(), outcomes.len());
    assert!(coordinator.record.rounds.contains_key("multiple"));
}
