//! Fleet telemetry acceptance tests (ISSUE 10):
//!
//! * **Storm exactness** — a 4-thread increment/histogram storm against
//!   one shared [`Registry`] snapshots to the arithmetic ground truth:
//!   relaxed atomics lose nothing, bucket sums equal counts, and the
//!   string-keyed `Counters` view renders the same numbers.
//! * **Ring semantics** — the flight recorder overwrites oldest-first,
//!   keeps an exact chronological tail, and its dumps carry the
//!   overwrite count.
//! * **Fleet merge + canonical codec** — per-tier registries merge into
//!   one snapshot (counters sum, gauges max, histograms add) and the
//!   `MKTL` payload encoding round-trips bit-exactly.
//! * **Recovery dumps** — [`ShardRouter::recover`] ships one flight dump
//!   per shard whose trail ends in the `Recover` span.

use std::sync::Arc;

use mikrr::data::synth;
use mikrr::kernels::Kernel;
use mikrr::persist::codec::Cursor;
use mikrr::persist::DurabilityConfig;
use mikrr::serve::router::{ServeConfig, ShardRouter};
use mikrr::streaming::StreamEvent;
use mikrr::telemetry::{
    FlightRecorder, HistId, MetricId, Registry, SpanKind, TelemetrySnapshot,
};
use mikrr::testutil::ScratchDir;

#[test]
fn four_thread_storm_snapshots_to_ground_truth() {
    const N: u64 = 10_000;
    let reg = Arc::new(Registry::new());
    let mut joins = Vec::new();
    for t in 0..4u64 {
        let reg = Arc::clone(&reg);
        joins.push(std::thread::spawn(move || {
            for i in 0..N {
                reg.inc(MetricId::Rounds);
                reg.add(MetricId::Routed, t + 1);
                reg.gauge_max(MetricId::MaxBatchRows, i);
                reg.record_hist(HistId::RoundLatencyUs, i % 100 + 1);
            }
        }));
    }
    for j in joins {
        j.join().unwrap();
    }

    let snap = reg.snapshot();
    assert_eq!(snap.counter(MetricId::Rounds), 4 * N);
    assert_eq!(snap.counter(MetricId::Routed), N * (1 + 2 + 3 + 4));
    assert_eq!(snap.counter(MetricId::MaxBatchRows), N - 1, "gauge keeps the high-water mark");
    let h = snap.hist(HistId::RoundLatencyUs);
    assert_eq!(h.count, 4 * N);
    assert_eq!(h.sum, 4 * (N / 100) * (100 * 101 / 2));
    assert_eq!((h.min, h.max), (1, 100));
    assert_eq!(h.buckets.iter().sum::<u64>(), h.count, "every sample lands in one bucket");
    assert!(h.p50() >= 1 && h.p99() <= h.max.next_power_of_two());

    // the string-keyed compatibility view renders the same numbers
    let c = reg.counters();
    assert_eq!(c.get("rounds"), 4 * N);
    assert_eq!(c.get("routed"), N * 10);
    assert_eq!(c.get("max_batch_rows"), N - 1);
    // idle registry → identical second snapshot
    assert_eq!(reg.snapshot(), snap);
}

#[test]
fn flight_recorder_wraps_and_keeps_the_newest_tail() {
    let mut rec = FlightRecorder::new(8);
    assert!(rec.is_empty());
    for i in 0..20u64 {
        rec.record(SpanKind::RoundStart, i, 2 * i);
    }
    assert_eq!((rec.len(), rec.capacity(), rec.total_recorded()), (8, 8, 20));

    // tail(n) is chronological and clipped to what survived the wraps
    let tail = rec.tail(3);
    assert_eq!(tail.iter().map(|e| e.a).collect::<Vec<_>>(), vec![17, 18, 19]);
    let all = rec.tail(100);
    assert_eq!(all.len(), 8);
    assert_eq!(all.iter().map(|e| e.a).collect::<Vec<_>>(), (12u64..20).collect::<Vec<_>>());
    assert!(all.windows(2).all(|w| w[0].t_us <= w[1].t_us));

    let dump = rec.dump("wrap-test".to_string());
    assert_eq!(dump.label, "wrap-test");
    assert_eq!(dump.total_recorded, 20);
    assert_eq!(dump.events, all);
    let text = dump.render_text();
    assert!(text.contains("wrap-test") && text.contains("round_start"), "{text}");
}

#[test]
fn per_tier_registries_merge_and_the_codec_round_trips() {
    let a = Registry::new();
    a.add(MetricId::Routed, 3);
    a.gauge_max(MetricId::MaxPendingRows, 5);
    a.record_hist(HistId::WalAppendUs, 5);
    let b = Registry::new();
    b.add(MetricId::Routed, 4);
    b.add(MetricId::ShardErrors, 2);
    b.gauge_max(MetricId::MaxPendingRows, 2);
    b.record_hist(HistId::WalAppendUs, 100);

    let mut snap = TelemetrySnapshot::new();
    a.merge_into(&mut snap);
    b.merge_into(&mut snap);
    snap.spans.push(mikrr::telemetry::SpanEvent {
        t_us: 1,
        kind: SpanKind::Publish,
        a: 4,
        b: 0,
    });
    assert_eq!(snap.counter(MetricId::Routed), 7, "counters sum across tiers");
    assert_eq!(snap.counter(MetricId::ShardErrors), 2);
    assert_eq!(snap.counter(MetricId::MaxPendingRows), 5, "gauges keep the max");
    let h = snap.hist(HistId::WalAppendUs);
    assert_eq!((h.count, h.sum, h.min, h.max), (2, 105, 5, 100));

    // canonical encoding: bit-exact round trip, byte-identical re-encode
    let mut wire = Vec::new();
    snap.encode(&mut wire);
    let mut cur = Cursor::new(&wire, "telemetry test");
    let back = TelemetrySnapshot::decode(&mut cur, "telemetry test").unwrap();
    assert_eq!(back, snap);
    let mut wire2 = Vec::new();
    back.encode(&mut wire2);
    assert_eq!(wire, wire2);
}

#[test]
fn recovery_ships_one_flight_dump_per_shard_ending_in_recover() {
    let dir = ScratchDir::new("telemetry-recovery");
    let d = synth::ecg_like(36, 4, 301);
    let extra = synth::ecg_like(12, 4, 302);
    let cfg = ServeConfig::default_for(Kernel::poly(2, 1.0), 2);
    let mut r = ShardRouter::bootstrap(&d.x, &d.y, cfg).unwrap();
    r.make_durable(
        dir.path(),
        DurabilityConfig { checkpoint_every: 1_000_000, keep_generations: 2 },
    )
    .unwrap();
    for i in 0..12 {
        r.ingest(StreamEvent::single(
            extra.x.row(i).to_vec(),
            extra.y[i],
            0,
            (i + 1) as u64,
        ));
    }
    let report = r.update_round();
    assert!(report.errors.is_empty(), "{:?}", report.errors);
    assert!(r.recovery_flight_dumps().is_empty(), "bootstrapped fleets carry no dumps");
    drop(r);

    let rec = ShardRouter::recover(dir.path()).unwrap();
    let dumps = rec.recovery_flight_dumps();
    assert_eq!(dumps.len(), rec.num_shards(), "one post-mortem dump per shard");
    for (i, dump) in dumps.iter().enumerate() {
        assert!(dump.label.contains(&format!("shard-{i}")), "{}", dump.label);
        let last = dump.events.last().expect("recovery trail is never empty");
        assert_eq!(last.kind, SpanKind::Recover);
        assert_eq!(last.a, i as u64);
    }
    // replayed rounds surface both in the registry and the compat view
    let replayed: u64 = dumps.iter().map(|d| d.events.last().unwrap().b).sum();
    assert!(replayed > 0, "the WAL suffix was replayed somewhere");
    assert_eq!(rec.telemetry().get(MetricId::WalRecordsReplayed), replayed);
    assert_eq!(rec.counters().get("wal_records_replayed"), replayed);
}
