//! Durable shards end to end: make a K-shard fleet crash-safe, stream
//! updates through the write-ahead log with checkpointing, "crash" by
//! dropping the router mid-stream, recover from disk, re-feed exactly the
//! lost events, and show the recovered predictions match an uninterrupted
//! control run.
//!
//! Run: `cargo run --release --example durable_serve`

use mikrr::data::synth;
use mikrr::kernels::Kernel;
use mikrr::persist::DurabilityConfig;
use mikrr::serve::{Placement, PredictRequest, QueryKind, ServeConfig, ShardRouter};
use mikrr::streaming::StreamEvent;

fn main() -> Result<(), mikrr::error::Error> {
    let dim = 8;
    let shards = 4;
    let boot = synth::ecg_like(240, dim, 1);
    let stream = synth::ecg_like(120, dim, 2);
    let queries = synth::ecg_like(10, dim, 3);

    let cfg = || {
        let mut c = ServeConfig::default_for(Kernel::poly(2, 1.0), shards);
        // content-hash placement: after a crash, the same event re-routes
        // to the same shard, which is what makes seq-based re-feed exact
        c.placement = Placement::Hash;
        c.base.outlier = None;
        c.base.with_uncertainty = true;
        c.base.snapshot_rollback = true;
        c.base.batch.max_batch = 4;
        c
    };
    let events: Vec<StreamEvent> = (0..stream.x.rows())
        .map(|i| StreamEvent::single(stream.x.row(i).to_vec(), stream.y[i], 0, (i + 1) as u64))
        .collect();

    // control: the whole stream, no crash
    let mut control = ShardRouter::bootstrap(&boot.x, &boot.y, cfg())?;
    for ev in &events {
        control.ingest(ev.clone());
    }
    while control.update_round().added() > 0 {}

    // durable run: WAL + snapshot every 4 rounds, "crash" after 70 events
    let dir = std::env::temp_dir().join(format!("mikrr-durable-serve-{}", std::process::id()));
    let mut fleet = ShardRouter::bootstrap(&boot.x, &boot.y, cfg())?;
    fleet.make_durable(&dir, DurabilityConfig { checkpoint_every: 4, keep_generations: 2 })?;
    for ev in &events[..70] {
        fleet.ingest(ev.clone());
        fleet.update_round();
    }
    let dc = fleet.durability_counters();
    println!(
        "before crash: high_seqs={:?} snapshots_written={} wal_records_appended={}",
        fleet.high_seqs(),
        dc.get("snapshots_written"),
        dc.get("wal_records_appended"),
    );
    drop(fleet); // the crash: every in-memory engine is gone

    // recovery: newest intact snapshots + idempotent WAL replay
    let mut recovered = ShardRouter::recover(&dir)?;
    let seqs = recovered.high_seqs();
    println!("recovered:    high_seqs={seqs:?}");

    // exactly-once re-feed of what the crash lost: anything above each
    // shard's recovered high-water mark, routed by the same content hash
    let k = recovered.num_shards();
    let mut refed = 0usize;
    for ev in &events {
        let s = recovered
            .placement()
            .shard_of(&ev.x, k)
            .expect("hash placement");
        if ev.seq > seqs[s] {
            recovered.ingest(ev.clone());
            refed += 1;
        }
    }
    while recovered.update_round().added() > 0 {}
    println!("re-fed {refed} lost events");

    let point = PredictRequest::new(queries.x.clone(), QueryKind::Mean);
    let bayes = PredictRequest::new(queries.x.clone(), QueryKind::MeanVar);
    let want = control.handle().query(&point)?;
    let got = recovered.handle().query(&point)?;
    let want_b = control.handle().query(&bayes)?;
    let got_b = recovered.handle().query(&bayes)?;
    let max_abs_gap = |g: &[f64], w: &[f64]| {
        g.iter().zip(w).map(|(a, b)| (a - b).abs()).fold(0.0f64, f64::max)
    };
    let max_dp = max_abs_gap(got.mean.as_slice(), want.mean.as_slice());
    let max_dmu = max_abs_gap(got_b.mean.as_slice(), want_b.mean.as_slice());
    let max_dvar = max_abs_gap(
        got_b.variance.as_deref().unwrap_or_default(),
        want_b.variance.as_deref().unwrap_or_default(),
    );
    println!(
        "recovered vs control: |Δpoint|={max_dp:.3e} |Δμ|={max_dmu:.3e} |Δσ²|={max_dvar:.3e}"
    );
    assert!(max_dp < 1e-8 && max_dmu < 1e-8 && max_dvar < 1e-8);
    println!("durable fleet recovered exactly (tolerance 1e-8)");

    let _ = std::fs::remove_dir_all(&dir);
    Ok(())
}
