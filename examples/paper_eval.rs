//! End-to-end paper reproduction driver: regenerates every table and
//! figure of the paper's evaluation (Tables IV–XII, Figs 2–8) on the
//! synthetic stand-in datasets, at a configurable scale.
//!
//! Run (scaled defaults, ~minutes):
//!   cargo run --release --example paper_eval
//! Quick smoke (~seconds):
//!   cargo run --release --example paper_eval -- --quick
//! Write a markdown report:
//!   cargo run --release --example paper_eval -- --out EXPERIMENTS_RUN.md
//!
//! Absolute seconds differ from the authors' 2016 testbed; the reproduced
//! quantities are the orderings (multiple < single < none), the log-gaps,
//! and the improvement folds of Tables IX/XII.

use mikrr::cli::{App, Arg};
use mikrr::config::Space;
use mikrr::coordinator::experiment::{run_kbr, run_krr, Strategy, StrategyReport};
use mikrr::data::synth;
use mikrr::data::Dataset;
use mikrr::kbr::KbrHyper;
use mikrr::kernels::Kernel;
use mikrr::error::Error;

struct Cell {
    id: &'static str,
    title: String,
    report: StrategyReport,
}

fn main() -> Result<(), Error> {
    let app = App::new("paper_eval", "regenerate all paper tables/figures")
        .arg(Arg::flag("train-ecg", "ECG basic training size").default("6000"))
        .arg(Arg::flag("train-drt", "DRT basic training size").default("640"))
        .arg(Arg::flag("drt-dim", "DRT feature dimension").default("20000"))
        .arg(Arg::flag("rounds", "rounds of +4/-2").default("10"))
        .arg(Arg::flag("seed", "rng seed").default("7"))
        .arg(Arg::flag("out", "write a markdown report here").default(""))
        .arg(Arg::switch("quick", "tiny sizes for smoke testing"))
        .arg(Arg::switch("skip-none", "skip the full-retrain baseline"));
    let m = app.parse(std::env::args().skip(1))?;

    let quick = m.is_set("quick");
    let rounds: usize = if quick { 3 } else { m.get_parse("rounds")? };
    let train_ecg: usize = if quick { 800 } else { m.get_parse("train-ecg")? };
    let train_drt: usize = if quick { 240 } else { m.get_parse("train-drt")? };
    let drt_dim: usize = if quick { 2_000 } else { m.get_parse("drt-dim")? };
    let seed: u64 = m.get_parse("seed")?;
    let strategies: Vec<Strategy> = if m.is_set("skip-none") {
        vec![Strategy::Multiple, Strategy::Single]
    } else {
        vec![Strategy::Multiple, Strategy::Single, Strategy::None]
    };

    println!(
        "paper_eval: ECG n={train_ecg} (M=21), DRT n={train_drt} (M={drt_dim}), \
         {rounds} rounds of +4/-2\n"
    );
    let ecg = synth::ecg_like(train_ecg + rounds * 4 + 2_000, 21, seed);
    let drt = synth::drt_like(train_drt + rounds * 4 + 160, drt_dim, 0.01, seed);

    let mut cells: Vec<Cell> = Vec::new();

    // ----- KRR: Tables IV-VIII / Figs 2-6 -----
    let krr_cells: [(&str, &Dataset, Kernel, Space, usize); 5] = [
        ("T4/F2 ECG-poly2", &ecg, Kernel::poly(2, 1.0), Space::Intrinsic, train_ecg),
        ("T5/F3 ECG-poly3", &ecg, Kernel::poly(3, 1.0), Space::Intrinsic, train_ecg),
        ("T6/F4 DRT-poly2", &drt, Kernel::poly(2, 1.0), Space::Empirical, train_drt),
        ("T7/F5 DRT-poly3", &drt, Kernel::poly(3, 1.0), Space::Empirical, train_drt),
        ("T8/F6 DRT-rbf", &drt, Kernel::rbf_radius(50.0), Space::Empirical, train_drt),
    ];
    for (id, data, kernel, space, train) in krr_cells {
        eprintln!("running {id} ...");
        let report = run_krr(data, &kernel, 0.5, space, train, rounds, 4, 2, seed, &strategies)?;
        let title = format!(
            "{id} (acc {:.2}%, agree {})",
            100.0 * report.accuracy,
            report.strategies_agree
        );
        println!("{}", report.record.render_table(&title));
        println!("{}", report.record.render_curves(&format!("{id} cumulative")));
        cells.push(Cell { id, title, report });
    }

    // ----- KBR: Tables X-XI / Figs 7-8 -----
    for (id, kernel) in [
        ("T10/F7 KBR-ECG-poly2", Kernel::poly(2, 1.0)),
        ("T11/F8 KBR-ECG-poly3", Kernel::poly(3, 1.0)),
    ] {
        eprintln!("running {id} ...");
        let report =
            run_kbr(&ecg, &kernel, KbrHyper::default(), train_ecg, rounds, 4, 2, seed, true)?;
        let title = format!("{id} (agree {})", report.strategies_agree);
        println!("{}", report.record.render_table(&title));
        println!("{}", report.record.render_curves(&format!("{id} cumulative")));
        cells.push(Cell { id, title, report });
    }

    // ----- Table IX (KRR averages + folds) -----
    println!("\n=== Table IX: KRR average computational time in a single round ===");
    println!(
        "{:<20} {:>12} {:>12} {:>12} {:>14}",
        "cell", "multiple(s)", "single(s)", "none(s)", "improvement"
    );
    for c in cells.iter().filter(|c| c.id.starts_with('T') && !c.id.contains("KBR")) {
        println!(
            "{:<20} {:>12.6} {:>12.6} {:>12.6} {:>13.2}x",
            c.id,
            c.report.record.mean_seconds("multiple"),
            c.report.record.mean_seconds("single"),
            c.report.record.mean_seconds("none"),
            c.report.record.improvement_fold("multiple", "single"),
        );
    }
    // ----- Table XII (KBR averages + folds) -----
    println!("\n=== Table XII: KBR average computational time in a single round ===");
    println!("{:<22} {:>12} {:>12} {:>14}", "cell", "multiple(s)", "single(s)", "improvement");
    for c in cells.iter().filter(|c| c.id.contains("KBR")) {
        println!(
            "{:<22} {:>12.6} {:>12.6} {:>13.2}x",
            c.id,
            c.report.record.mean_seconds("multiple"),
            c.report.record.mean_seconds("single"),
            c.report.record.improvement_fold("multiple", "single"),
        );
    }

    // optional markdown report
    let out = m.get("out").unwrap_or("");
    if !out.is_empty() {
        let mut md = String::from("# paper_eval run\n\n");
        md.push_str(&format!(
            "ECG n={train_ecg} M=21; DRT n={train_drt} M={drt_dim}; {rounds} rounds +4/-2; seed {seed}\n\n"
        ));
        for c in &cells {
            md.push_str(&format!("## {}\n\n```\n{}\n{}\n```\n\n",
                c.title,
                c.report.record.render_table(c.id),
                c.report.record.render_curves("cumulative"),
            ));
            md.push_str(&format!(
                "- mean/round: multiple {:.6}s, single {:.6}s, none {:.6}s; fold (multi vs single) {:.2}x\n\n",
                c.report.record.mean_seconds("multiple"),
                c.report.record.mean_seconds("single"),
                c.report.record.mean_seconds("none"),
                c.report.record.improvement_fold("multiple", "single"),
            ));
        }
        std::fs::write(out, md)?;
        println!("\nwrote {out}");
    }

    // sanity: the paper's qualitative claims must hold
    for c in &cells {
        assert!(c.report.strategies_agree, "{}: strategies disagree", c.id);
        let m_ = c.report.record.mean_seconds("multiple");
        let s_ = c.report.record.mean_seconds("single");
        assert!(m_ < s_, "{}: multiple ({m_}) !< single ({s_})", c.id);
    }
    println!("\npaper_eval OK — all cells reproduce the paper's orderings.");
    Ok(())
}
