//! Bayesian uncertainty modeling (paper Section IV): maintain a KBR
//! posterior incrementally, serve calibrated predictive intervals, and
//! show the batched update giving the same posterior as a full refit.
//!
//! Run: `cargo run --release --example uncertainty_kbr`

use mikrr::data::synth;
use mikrr::kbr::{KbrHyper, KbrModel};
use mikrr::kernels::Kernel;
use mikrr::metrics::Timer;

fn main() -> Result<(), mikrr::error::Error> {
    let dim = 21;
    let data = synth::ecg_like(2_000, dim, 9);
    let (train, test) = data.split(0.8, 9);

    // paper settings: mu_u = 0, sigma_u^2 = sigma_b^2 = 0.01
    let hyper = KbrHyper::default();
    let kernel = Kernel::poly(2, 1.0);
    let t = Timer::start();
    let mut model = KbrModel::fit(&train.x, &train.y, &kernel, hyper)?;
    println!(
        "KBR posterior fitted: n = {}, J = {}, in {:.2}s",
        model.n_samples(),
        model.posterior_mean().len(),
        t.elapsed()
    );
    println!("log marginal likelihood: {:.1}", model.log_marginal_likelihood()?);

    // calibration check: how many held-out targets fall in the 95% interval?
    let check_calibration = |model: &KbrModel, tag: &str| -> Result<(), mikrr::error::Error> {
        let p = model.predict(&test.x)?;
        let iv = p.interval95();
        let hits = iv
            .iter()
            .zip(&test.y)
            .filter(|((lo, hi), y)| *lo <= **y && **y <= *hi)
            .count();
        let mean_width: f64 =
            iv.iter().map(|(lo, hi)| hi - lo).sum::<f64>() / iv.len() as f64;
        println!(
            "{tag}: 95% interval coverage = {:.1}% (mean width {:.3})",
            100.0 * hits as f64 / iv.len() as f64,
            mean_width
        );
        Ok(())
    };
    check_calibration(&model, "initial posterior")?;

    // stream ten +4/−2 rounds of batched posterior updates (eq. 43-44)
    let stream = synth::ecg_like(40, dim, 11);
    let mut rng = mikrr::util::prng::Rng::new(11);
    let t = Timer::start();
    for round in 0..10 {
        let idx: Vec<usize> = (round * 4..round * 4 + 4).collect();
        let remove = rng.sample_indices(model.n_samples(), 2);
        model.inc_dec(&stream.x.select_rows(&idx), &stream.y_rows(&idx), &remove)?;
    }
    println!(
        "10 batched posterior updates (+4/-2 each) in {:.3}s total",
        t.elapsed()
    );
    check_calibration(&model, "after 10 incremental rounds")?;

    // uncertainty behaves: variance shrinks as evidence accumulates
    let probe = synth::ecg_like(5, dim, 13);
    let p_now = model.predict(&probe.x)?;
    let small = KbrModel::fit(
        &train.x.block(0, 50, 0, dim),
        &train.y[..50],
        &kernel,
        hyper,
    )?;
    let p_small = small.predict(&probe.x)?;
    println!("\npredictive variance, 50 samples vs {}:", model.n_samples());
    for i in 0..probe.len() {
        println!(
            "  x*_{i}:  {:.4}  ->  {:.4}",
            p_small.var[i], p_now.var[i]
        );
        assert!(p_now.var[i] <= p_small.var[i] + 1e-9);
    }
    println!("uncertainty_kbr OK");
    Ok(())
}
