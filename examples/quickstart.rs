//! Quickstart: fit an incremental KRR model, stream a few +4/−2 rounds,
//! and confirm the incremental model equals a from-scratch retrain.
//!
//! Run: `cargo run --release --example quickstart`

use mikrr::data::synth;
use mikrr::kernels::Kernel;
use mikrr::krr::intrinsic::IntrinsicKrr;
use mikrr::krr::{classification_accuracy, KrrModel};
use mikrr::metrics::Timer;

fn main() -> Result<(), mikrr::error::Error> {
    // 1) a synthetic ECG-like dataset: N=3000 samples, M=21 features
    let data = synth::ecg_like(3_000, 21, 42);
    let (train, test) = data.split(0.8, 42);
    println!("dataset: {} (train {} / test {})", data.name, train.len(), test.len());

    // 2) fit intrinsic-space KRR with the paper's poly2 kernel, rho = 0.5
    let kernel = Kernel::poly(2, 1.0);
    let t = Timer::start();
    let mut model = IntrinsicKrr::fit(&train.x, &train.y, &kernel, 0.5)?;
    println!("bootstrap fit: J = {} intrinsic dims in {:.3}s", model.j(), t.elapsed());

    // keep a mirror of the dataset so we can check the paper's invariant
    let mut x_cur = train.x.clone();
    let mut y_cur = train.y.clone();

    // 3) stream five +4/−2 rounds — each is ONE batched rank-6 update
    let stream = synth::ecg_like(20, 21, 7);
    let mut rng = mikrr::util::prng::Rng::new(7);
    for round in 0..5 {
        let idx: Vec<usize> = (round * 4..round * 4 + 4).collect();
        let mut remove = rng.sample_indices(model.n_samples(), 2);
        remove.sort_unstable();
        let t = Timer::start();
        model.inc_dec(&stream.x.select_rows(&idx), &stream.y_rows(&idx), &remove)?;
        println!(
            "round {round}: +4/-2 in {:.2}ms  (n = {})",
            t.elapsed() * 1e3,
            model.n_samples()
        );
        // mirror the edit
        x_cur.remove_rows(&remove)?;
        for (i, &ri) in remove.iter().enumerate() {
            y_cur.remove(ri - i);
        }
        x_cur = x_cur.vcat(&stream.x.select_rows(&idx))?;
        y_cur.extend(stream.y_rows(&idx));
    }

    // 4) accuracy, paper style (sign threshold)
    let pred = model.predict(&test.x)?;
    println!(
        "held-out accuracy: {:.2}%",
        100.0 * classification_accuracy(&pred, &test.y)
    );

    // 5) the paper's invariant: incremental == retrain on the edited set
    let fresh = IntrinsicKrr::fit(&x_cur, &y_cur, &kernel, 0.5)?;
    let p_fresh = fresh.predict(&test.x)?;
    let max_diff = pred
        .iter()
        .zip(&p_fresh)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max);
    println!("max |incremental - retrain| prediction diff: {max_diff:.2e}");
    assert!(max_diff < 1e-6, "incremental must equal retrain");
    println!("quickstart OK");
    Ok(())
}
