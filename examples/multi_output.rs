//! Multi-output targets + duplicate-input folding.
//!
//! A fleet of D sensors observes the same inputs: instead of running D
//! independent engines (D factorizations, D Woodbury updates per round),
//! one engine maintains ONE inverse with a (J, D) coefficient block.
//! Repeated inputs — the hot-sensor pattern, where the same reading
//! re-arrives — fold into a multiplicity-weighted row instead of growing
//! the kernel system.
//!
//! Run: `cargo run --release --example multi_output`

use mikrr::config::Space;
use mikrr::coordinator::engine::Engine;
use mikrr::data::synth;
use mikrr::kernels::Kernel;
use mikrr::linalg::Mat;
use mikrr::metrics::{mae_multi, rmse_multi, Timer};

/// Derive a (N, D) target matrix from one scalar label stream: each
/// "sensor" column is a different calibrated transform of the signal.
fn multi_targets(y: &[f64], d: usize) -> Mat {
    Mat::from_fn(y.len(), d, |i, j| {
        let g = 1.0 + 0.5 * j as f64;
        g * y[i] + 0.1 * (j as f64) * (y[i] * y[i] - 0.5)
    })
}

fn main() -> Result<(), mikrr::error::Error> {
    let (dim, d_out) = (21, 3);
    let base = synth::ecg_like(600, dim, 1);
    let y = multi_targets(&base.y, d_out);

    // one engine, one maintained inverse, D coefficient columns
    let t = Timer::start();
    let mut folding =
        Engine::fit_multi(&base.x, &y, &Kernel::poly(2, 1.0), 0.5, Space::Intrinsic, true)?;
    folding.set_fold_eps(Some(1e-12));
    let mut plain =
        Engine::fit_multi(&base.x, &y, &Kernel::poly(2, 1.0), 0.5, Space::Intrinsic, true)?;
    println!(
        "bootstrap: n = {}, D = {} outputs, two engines in {:.2}s",
        folding.n_samples(),
        folding.n_outputs(),
        t.elapsed()
    );

    // stream rounds where half of each batch repeats rows the store has
    // already seen (hot sensors re-reporting), plus an eviction each round
    let fresh = synth::ecg_like(200, dim, 77);
    let yf = multi_targets(&fresh.y, d_out);
    let mut folded_rounds = 0usize;
    for round in 0..25 {
        let mut xb = Mat::default();
        let mut yb = Mat::default();
        for k in 0..4 {
            let i = round * 4 + k;
            if k % 2 == 0 {
                // fresh observation
                xb.push_row(fresh.x.row(i))?;
                yb.push_row(yf.row(i))?;
            } else {
                // exact repeat of a stored row with a re-measured target;
                // drawn from rows 100.. so the evictions below (head
                // indices) never land on a multiplicity-weighted row,
                // keeping the two engines describing identical data
                let (xs, ys) = folding.training_view();
                let j = 100 + (round * 13 + k) % 400;
                let (xr, yr) = (xs.row(j).to_vec(), ys.row(j).to_vec());
                xb.push_row(&xr)?;
                yb.push_row(&yr)?;
            }
        }
        let evict = [round % 50];
        folding.inc_dec_multi(&xb, &yb, &evict)?;
        plain.inc_dec_multi(&xb, &yb, &evict)?;
        folded_rounds += folding.last_round_folds();
    }
    println!(
        "after 25 rounds: folded engine n = {} vs unfolded n = {} ({} rows folded)",
        folding.n_samples(),
        plain.n_samples(),
        folded_rounds
    );
    let max_mult = folding
        .multiplicities()
        .iter()
        .fold(1.0f64, |a, &b| a.max(b));
    println!("hottest stored row carries multiplicity {max_mult}");

    // both engines describe the same posterior: held-out parity + accuracy
    let test = synth::ecg_like(400, dim, 999);
    let truth = multi_targets(&test.y, d_out);
    let pf = folding.predict_multi(&test.x)?;
    let pp = plain.predict_multi(&test.x)?;
    let gap = rmse_multi(&pf, &pp)?;
    println!("folded vs unfolded prediction gap (pooled rmse): {:.2e}", gap.pooled);

    let rmse = rmse_multi(&pf, &truth)?;
    let mae = mae_multi(&pf, &truth)?;
    for j in 0..d_out {
        println!(
            "  output {j}: rmse = {:.4}  mae = {:.4}",
            rmse.per_column[j], mae.per_column[j]
        );
    }
    println!("  pooled:   rmse = {:.4}  mae = {:.4}", rmse.pooled, mae.pooled);

    // the KBR twin shares one posterior across all D outputs: one
    // variance column covers every target
    let (mu, var) = folding.predict_with_uncertainty_multi(&test.x)?;
    let mut iv = Vec::new();
    mikrr::kbr::interval95_from_into(&mu.col(0), &var, &mut iv);
    let covered = iv
        .iter()
        .zip(0..truth.rows())
        .filter(|((lo, hi), i)| truth[(*i, 0)] >= *lo && truth[(*i, 0)] <= *hi)
        .count();
    println!(
        "95% interval coverage on output 0: {:.1}% ({covered} / {})",
        100.0 * covered as f64 / truth.rows() as f64,
        truth.rows()
    );
    Ok(())
}
