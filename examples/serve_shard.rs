//! The sharded serving layer end to end: a sensor fleet streams into one
//! fusion-center sink, a fan-out thread splits the pooled stream across
//! per-shard sinks, K engine shards run fused inc/dec rounds on their
//! slices and publish epoch snapshots, and a concurrent client fleet
//! serves single-row predictions through the micro-batching front-end —
//! reads never block on updates, and the headline is throughput under
//! concurrent updates.
//!
//! Run: `cargo run --release --example serve_shard`

use mikrr::coordinator::CoordinatorConfig;
use mikrr::data::synth;
use mikrr::kernels::Kernel;
use mikrr::krr::classification_accuracy;
use mikrr::metrics::Timer;
use mikrr::serve::{
    MicroBatchPolicy, MicroBatchServer, Placement, PredictRequest, QueryKind,
    ServeConfig, ShardRouter,
};
use mikrr::streaming::batcher::BatchPolicy;
use mikrr::streaming::fanout::spawn_fanout;
use mikrr::streaming::outlier::OutlierConfig;
use mikrr::streaming::sink::SinkNode;
use mikrr::streaming::source::{SensorNode, SourceConfig};
use std::time::Duration;

fn main() -> Result<(), mikrr::error::Error> {
    let dim = 21;
    let shards = 4;
    let sensors = 4;
    let per_sensor = 100;

    // bootstrap K shard engines on an initial pool (row i -> shard i mod K)
    let base_data = synth::ecg_like(2_000, dim, 1);
    let cfg = ServeConfig {
        shards,
        placement: Placement::RoundRobin,
        base: CoordinatorConfig {
            kernel: Kernel::poly(2, 1.0),
            ridge: 0.5,
            space: None,
            batch: BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(25) },
            outlier: Some(OutlierConfig { z_threshold: 5.0, max_removals: 2 }),
            with_uncertainty: true,
            snapshot_rollback: false,
            fold_eps: None,
        },
    };
    let t = Timer::start();
    let mut router = ShardRouter::bootstrap(&base_data.x, &base_data.y, cfg)?;
    println!(
        "router up: {} shards in {:?} space, bootstrap {:.2}s, n = {} ({} per shard)",
        router.num_shards(),
        router.space(),
        t.elapsed(),
        router.n_samples(),
        router.shard(0).n_samples(),
    );

    // sensor fleet -> one pooled sink -> fan-out into per-shard sinks
    let mut pooled = SinkNode::new(64);
    let mut sensor_handles = Vec::new();
    for sid in 0..sensors {
        let shard_data = synth::ecg_like(per_sensor, dim, 100 + sid as u64);
        let scfg = SourceConfig {
            source_id: sid,
            outlier_rate: 0.05,
            delay: Some(Duration::from_micros(200)),
            seed: 30 + sid as u64,
        };
        sensor_handles.push(SensorNode::new(shard_data, scfg).spawn(pooled.sender()));
    }
    pooled.seal();
    let mut shard_sinks: Vec<SinkNode> = (0..shards).map(|_| SinkNode::new(32)).collect();
    let shard_txs: Vec<_> = shard_sinks.iter().map(|s| s.sender()).collect();
    for s in &mut shard_sinks {
        s.seal();
    }
    let mut rr = 0usize;
    let fanout = spawn_fanout(pooled, shard_txs, move |_| {
        let s = rr;
        rr += 1;
        s
    });

    // the micro-batched prediction front-end over the epoch-published
    // read path, hammered by a client fleet while updates run
    let server = MicroBatchServer::spawn(
        router.handle(),
        dim,
        MicroBatchPolicy { max_rows: 64, max_wait: Duration::from_micros(500) },
    );
    let stop = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false));
    let mut client_handles = Vec::new();
    for c in 0..3 {
        let mut client = server.client();
        let stop_c = std::sync::Arc::clone(&stop);
        client_handles.push(std::thread::spawn(move || {
            let queries = synth::ecg_like(64, 21, 500 + c);
            let mut served = 0u64;
            let mut lat = mikrr::metrics::LatencyHist::new();
            let mut i = 0usize;
            while !stop_c.load(std::sync::atomic::Ordering::Relaxed) {
                let t = Timer::start();
                let req =
                    PredictRequest::single(queries.x.row(i % 64), QueryKind::MeanVar);
                let resp = client.query(req).unwrap();
                let (_mu, _var) = (resp.scalar(), resp.variance_at(0));
                lat.record(t.elapsed());
                served += 1;
                i += 1;
            }
            (served, lat)
        }));
    }

    // drive shard rounds until the stream drains
    let t = Timer::start();
    let report = router.run_per_shard(&mut shard_sinks, usize::MAX)?;
    let wall = t.elapsed();
    stop.store(true, std::sync::atomic::Ordering::Relaxed);
    for h in sensor_handles {
        h.join().expect("sensor thread");
    }
    let forwarded = fanout.join().expect("fanout thread");

    let mut total_served = 0u64;
    for (c, h) in client_handles.into_iter().enumerate() {
        let (served, lat) = h.join().expect("client thread");
        total_served += served;
        println!("client {c}: {served} predictions, latency {}", lat.summary());
    }
    let stats = server.shutdown();

    let (added, removed) = (report.added(), report.removed());
    println!(
        "stream done: {forwarded} forwarded, {added} applied, {removed} outliers pruned, \
         {} shard rounds ({} shard errors) in {wall:.2}s ({:.0} samples/s ingest)",
        report.outcomes.len(),
        report.errors.len(),
        added as f64 / wall,
    );
    println!(
        "serving under updates: {total_served} predictions ({:.0}/s) in {} micro-batches \
         (largest {} rows); per-shard epochs now {:?}",
        total_served as f64 / wall,
        stats.batches,
        stats.max_batch_rows,
        router.handle().epochs(),
    );

    // one explicit outlier-eviction round across every shard
    let evict = router.evict_outliers();
    println!(
        "eviction round: {} samples removed across {shards} shards",
        evict.removed()
    );

    // held-out quality through the DC-KRR averaged read path
    let test = synth::ecg_like(2_000, dim, 999);
    let handle = router.handle();
    let pred = handle.query(&PredictRequest::new(test.x.clone(), QueryKind::Mean))?;
    println!(
        "held-out accuracy after stream: {:.2}%",
        100.0 * classification_accuracy(pred.mean.as_slice(), &test.y)
    );
    let probe = handle.query(&PredictRequest::new(
        test.x.block(0, 3, 0, dim),
        QueryKind::MeanVar,
    ))?;
    let var = probe.variance.as_deref().unwrap_or_default();
    println!(
        "uncertainty fan-in sample: mu = {:?}, 95% half-widths = {:?}",
        probe
            .mean
            .as_slice()
            .iter()
            .map(|m| (m * 100.0).round() / 100.0)
            .collect::<Vec<_>>(),
        var.iter()
            .map(|v| (1.96 * v.sqrt() * 100.0).round() / 100.0)
            .collect::<Vec<_>>(),
    );
    Ok(())
}
