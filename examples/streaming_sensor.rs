//! The paper's motivating workload (Fig. 1): a fleet of sensor nodes
//! streams labelled observations to a fusion center; the coordinator pools
//! them, batches them, prunes outliers decrementally, and keeps the model
//! live while serving predictions.
//!
//! Run: `cargo run --release --example streaming_sensor`

use mikrr::coordinator::{Coordinator, CoordinatorConfig};
use mikrr::data::synth;
use mikrr::kernels::Kernel;
use mikrr::krr::classification_accuracy;
use mikrr::metrics::Timer;
use mikrr::streaming::batcher::BatchPolicy;
use mikrr::streaming::outlier::OutlierConfig;
use mikrr::streaming::sink::SinkNode;
use mikrr::streaming::source::{SensorNode, SourceConfig};
use std::time::Duration;

fn main() -> Result<(), mikrr::error::Error> {
    let dim = 21;
    let sensors = 4;
    let per_sensor = 100;

    // bootstrap the fusion-center model on an initial pool
    let base = synth::ecg_like(4_000, dim, 1);
    let cfg = CoordinatorConfig {
        kernel: Kernel::poly(2, 1.0),
        ridge: 0.5,
        space: None, // advisor routes: N >> M -> intrinsic
        batch: BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(25) },
        outlier: Some(OutlierConfig { z_threshold: 5.0, max_removals: 2 }),
        with_uncertainty: false,
        snapshot_rollback: false,
        fold_eps: None,
    };
    let t = Timer::start();
    let mut coordinator = Coordinator::bootstrap(&base.x, &base.y, cfg)?;
    println!(
        "fusion center up: {:?} space, bootstrap {:.2}s, n = {}",
        coordinator.space(),
        t.elapsed(),
        coordinator.handle().n_samples()
    );

    // spawn the sensor fleet; 5% of readings are corrupted (outliers)
    let mut sink = SinkNode::new(64);
    let mut handles = Vec::new();
    for sid in 0..sensors {
        let shard = synth::ecg_like(per_sensor, dim, 100 + sid as u64);
        let scfg = SourceConfig {
            source_id: sid,
            outlier_rate: 0.05,
            delay: Some(Duration::from_micros(200)),
            seed: 30 + sid as u64,
        };
        handles.push(SensorNode::new(shard, scfg).spawn(sink.sender()));
    }
    // all sender handles are out: seal so the stream drains to completion
    // the instant the fleet finishes (no trailing max_wait timeout)
    sink.seal();

    // a prediction client running against the live model
    let handle = coordinator.handle();
    let stop = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false));
    let stop_c = std::sync::Arc::clone(&stop);
    let client = std::thread::spawn(move || {
        let queries = synth::ecg_like(32, dim, 500);
        let mut lat = mikrr::metrics::LatencyHist::new();
        while !stop_c.load(std::sync::atomic::Ordering::Relaxed) {
            let t = Timer::start();
            let _ = handle.predict(&queries.x).unwrap();
            lat.record(t.elapsed());
        }
        lat
    });

    // drive the stream to exhaustion
    let t = Timer::start();
    let outcomes = coordinator.run(&mut sink, usize::MAX)?;
    let wall = t.elapsed();
    stop.store(true, std::sync::atomic::Ordering::Relaxed);
    for h in handles {
        h.join().expect("sensor thread");
    }
    let client_lat = client.join().expect("client thread");

    let added: usize = outcomes.iter().map(|o| o.added).sum();
    let removed: usize = outcomes.iter().map(|o| o.removed).sum();
    println!(
        "stream done: {added} arrivals, {removed} outliers pruned, {} rounds in {wall:.2}s \
         ({:.0} samples/s ingest)",
        outcomes.len(),
        added as f64 / wall
    );
    println!("update latency: {}", coordinator.update_latency.summary());
    println!("prediction latency (32-row batches): {}", client_lat.summary());
    println!("counters: {}", coordinator.counters.render());

    let test = synth::ecg_like(2_000, dim, 999);
    let pred = coordinator.handle().predict(&test.x)?;
    println!(
        "held-out accuracy after stream: {:.2}%",
        100.0 * classification_accuracy(&pred, &test.y)
    );
    Ok(())
}
