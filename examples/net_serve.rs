//! The network serving front-end end to end: a shard fleet behind a TCP
//! reactor, a client fleet mixing predict and update frames over real
//! sockets, an ingest consumer feeding acked updates into router rounds,
//! a live `MKTL` telemetry pull rendering the merged fleet snapshot,
//! and a deliberate over-budget burst showing admission control shedding
//! exactly the excess instead of queueing it.
//!
//! Run: `cargo run --release --example net_serve`

use std::time::{Duration, Instant};

use mikrr::data::synth;
use mikrr::kernels::Kernel;
use mikrr::net::{Frame, NetClient, NetConfig, NetServer};
use mikrr::serve::{
    MicroBatchPolicy, Placement, PredictRequest, QueryKind, ServeConfig, ShardRouter,
};
use mikrr::streaming::StreamEvent;

fn main() -> Result<(), mikrr::error::Error> {
    let dim = 8;
    let boot = synth::ecg_like(240, dim, 1);
    let mut cfg = ServeConfig::default_for(Kernel::poly(2, 1.0), 2);
    cfg.placement = Placement::RoundRobin;
    cfg.base.outlier = None;
    cfg.base.with_uncertainty = true;
    let mut router = ShardRouter::bootstrap(&boot.x, &boot.y, cfg)?;
    println!(
        "router up: {} shards, n = {}",
        router.num_shards(),
        router.n_samples()
    );

    // the reactor: epoll-driven accept loop, micro-batch window shared
    // with the in-process server, admission control in front of both paths
    let (server, updates) =
        NetServer::spawn(router.handle(), dim, NetConfig::default())?;
    let addr = server.addr();
    println!("serving on {addr}");

    // the ingest consumer: every acked update frame lands here; routing
    // and flushing stay the caller's decision, exactly like SinkNode runs
    let consumer = std::thread::spawn(move || {
        let mut pending = 0usize;
        while let Ok(ev) = updates.recv() {
            router.ingest(ev);
            pending += 1;
            if pending % 16 == 0 {
                router.update_round();
            }
        }
        let report = router.update_round();
        (router, pending, report)
    });

    // a client fleet over real sockets: 7:1 predict:update mix, point and
    // posterior queries alternating, shed requests retried after the hint
    let t0 = Instant::now();
    let mut joins = Vec::new();
    for c in 0..3u64 {
        joins.push(std::thread::spawn(move || {
            let q = synth::ecg_like(32, 8, 500 + c);
            let mut client = NetClient::connect(addr, 1 << 20).unwrap();
            client.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
            let mut served = 0usize;
            for i in 0..200usize {
                if i % 8 == 7 {
                    let ev = StreamEvent::single(
                        q.x.row(i % 32).to_vec(),
                        q.y[i % 32],
                        c as usize,
                        i as u64,
                    );
                    client.send_update(&ev).unwrap();
                    match client.recv().unwrap() {
                        Frame::Ack { .. } | Frame::RetryAfter { .. } => {}
                        f => panic!("unexpected frame {f:?}"),
                    }
                } else {
                    let want =
                        if i % 2 == 0 { QueryKind::Mean } else { QueryKind::MeanVar };
                    let req = PredictRequest::single(q.x.row(i % 32), want);
                    loop {
                        match client.query(&req) {
                            Ok(_) => break,
                            Err(e) if e.is_transient() => {
                                std::thread::sleep(Duration::from_millis(1))
                            }
                            Err(e) => panic!("predict failed: {e}"),
                        }
                    }
                    served += 1;
                }
            }
            served
        }));
    }
    let served: usize = joins.into_iter().map(|j| j.join().unwrap()).sum();
    let wall = t0.elapsed().as_secs_f64();
    let live = server.live();
    println!(
        "storm done: {served} predicts over sockets in {wall:.2}s ({:.0}/s), \
         {} conns accepted, {} shed so far",
        served as f64 / wall,
        live.accepted,
        live.shed,
    );

    // live observability over the same socket: the MKTL stats frame pulls
    // the merged fleet snapshot — reactor counters, shard round phases,
    // window occupancy, and the flight-recorder tail — without perturbing
    // the registries it reads (a second idle pull is byte-identical)
    {
        let mut c = NetClient::connect(addr, 1 << 20).unwrap();
        c.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        let snap = c.stats()?;
        println!("\n--- live MKTL telemetry snapshot ---\n{}", snap.render_text());
    }

    let stats = server.shutdown();
    let (router, ingested, report) = consumer.join().unwrap();
    println!(
        "ingest: {ingested} events through the socket path, final round added {}, \
         n = {}",
        report.added(),
        router.n_samples()
    );
    println!(
        "window occupancy p99: {:.1} rows (high-water {} of budget); counters:\n{}",
        stats.window_occupancy.percentile(99.0),
        stats.max_pending_rows,
        stats.counters.render(),
    );

    // admission control, demonstrated exactly: a budget of 4 rows, a long
    // window, and a 12-request burst — the reactor answers the first 4 and
    // sheds the other 8 immediately (bounded memory, no hidden queue)
    let burst_router = {
        let boot = synth::ecg_like(240, dim, 9);
        let mut cfg = ServeConfig::default_for(Kernel::poly(2, 1.0), 2);
        cfg.base.outlier = None;
        ShardRouter::bootstrap(&boot.x, &boot.y, cfg)?
    };
    let burst_cfg = NetConfig {
        batch: MicroBatchPolicy { max_rows: 64, max_wait: Duration::from_millis(100) },
        pending_budget: 4,
        max_inflight_per_conn: 16,
        ..NetConfig::default()
    };
    let (server, _rx) = NetServer::spawn(burst_router.handle(), dim, burst_cfg)?;
    let q = synth::ecg_like(12, dim, 10);
    let mut client = NetClient::connect(server.addr(), 1 << 20).unwrap();
    client.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    for i in 0..12 {
        client.send_predict(&PredictRequest::single(q.x.row(i), QueryKind::Mean))?;
    }
    let (mut answered, mut shed) = (0, 0);
    for _ in 0..12 {
        match client.recv()? {
            Frame::Response { .. } => answered += 1,
            Frame::RetryAfter { .. } => shed += 1,
            f => panic!("unexpected frame {f:?}"),
        }
    }
    let stats = server.shutdown();
    println!(
        "burst of 12 against budget 4: {answered} answered, {shed} shed \
         (max pending rows ever: {})",
        stats.max_pending_rows
    );
    assert_eq!((answered, shed), (4, 8));
    println!("net_serve OK");
    Ok(())
}
