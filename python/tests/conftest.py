"""Test config: enable x64 so the pure-jnp oracles run in real float64.

The Pallas kernels and AOT entries cast to float32 explicitly (the PJRT
interchange dtype), so this only upgrades the reference computations and
the tolerance checks against them.
"""

import jax

jax.config.update("jax_enable_x64", True)
