"""L1 correctness: every Pallas kernel vs the pure-jnp oracle in ref.py.

Includes hypothesis sweeps over shapes/seeds — the CORE correctness signal
for the compile path.
"""

import math

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import feature_map, gram, ref, woodbury

RNG = np.random.default_rng(0)


def _x(n, m, seed=0, scale=1.0):
    return np.random.default_rng(seed).normal(size=(n, m)).astype(np.float32) * scale


# ---------------------------------------------------------------------------
# Gram kernels
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("degree", [1, 2, 3])
@pytest.mark.parametrize("shape", [(7, 5, 3), (128, 128, 21), (130, 37, 21), (1, 1, 1)])
def test_gram_poly_matches_ref(degree, shape):
    n, p, m = shape
    x, y = _x(n, m, 1), _x(p, m, 2)
    got = gram.gram_poly(x, y, degree=degree, bm=32, bn=32)
    want = ref.gram_poly(jnp.asarray(x), jnp.asarray(y), degree=degree)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("gamma", [0.5, 1.0 / (2 * 50.0**2)])
@pytest.mark.parametrize("shape", [(9, 6, 4), (128, 64, 21), (65, 129, 8)])
def test_gram_rbf_matches_ref(gamma, shape):
    n, p, m = shape
    x, y = _x(n, m, 3), _x(p, m, 4)
    got = gram.gram_rbf(x, y, gamma=gamma, bm=32, bn=32)
    want = ref.gram_rbf(jnp.asarray(x), jnp.asarray(y), gamma=gamma)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)


def test_gram_poly_symmetric_psd():
    x = _x(40, 8, 5)
    k = np.asarray(gram.gram_poly(x, x, degree=2, bm=16, bn=16), dtype=np.float64)
    np.testing.assert_allclose(k, k.T, atol=1e-5)
    w = np.linalg.eigvalsh((k + k.T) / 2)
    assert w.min() > -1e-3


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 70),
    p=st.integers(1, 70),
    m=st.integers(1, 24),
    degree=st.integers(1, 3),
    seed=st.integers(0, 2**31 - 1),
)
def test_gram_poly_hypothesis(n, p, m, degree, seed):
    x, y = _x(n, m, seed), _x(p, m, seed + 1)
    got = gram.gram_poly(x, y, degree=degree, bm=16, bn=16)
    want = ref.gram_poly(jnp.asarray(x), jnp.asarray(y), degree=degree)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=3e-4, atol=3e-4)


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(1, 60),
    p=st.integers(1, 60),
    m=st.integers(1, 16),
    seed=st.integers(0, 2**31 - 1),
)
def test_gram_rbf_hypothesis(n, p, m, seed):
    x, y = _x(n, m, seed), _x(p, m, seed + 7)
    got = gram.gram_rbf(x, y, gamma=0.3, bm=16, bn=16)
    want = ref.gram_rbf(jnp.asarray(x), jnp.asarray(y), gamma=0.3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# Feature map
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("degree", [1, 2, 3])
@pytest.mark.parametrize("m", [3, 8, 21])
def test_phi_poly_matches_ref(degree, m):
    x = _x(17, m, 11)
    got = feature_map.phi_poly(x, degree=degree, bm=8)
    want = ref.phi_poly(jnp.asarray(x), degree=degree)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("degree", [2, 3])
def test_phi_poly_reproduces_kernel(degree):
    """phi(x) . phi(y) == (x.y + 1)^degree — the defining identity."""
    m = 6
    x, y = _x(12, m, 21), _x(9, m, 22)
    px = np.asarray(feature_map.phi_poly(x, degree=degree, bm=8), dtype=np.float64)
    py = np.asarray(feature_map.phi_poly(y, degree=degree, bm=8), dtype=np.float64)
    k_from_phi = px @ py.T
    k_direct = np.asarray(ref.gram_poly(jnp.asarray(x), jnp.asarray(y), degree=degree))
    np.testing.assert_allclose(k_from_phi, k_direct, rtol=2e-4, atol=2e-4)


def test_intrinsic_dim():
    assert ref.intrinsic_dim(21, 2) == 253
    assert ref.intrinsic_dim(21, 3) == 2024
    assert feature_map.monomial_table(21, 2)[1].shape[0] == 253


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(1, 40),
    m=st.integers(1, 12),
    degree=st.integers(1, 3),
    seed=st.integers(0, 2**31 - 1),
)
def test_phi_poly_hypothesis(n, m, degree, seed):
    x = _x(n, m, seed)
    got = feature_map.phi_poly(x, degree=degree, bm=8)
    want = ref.phi_poly(jnp.asarray(x), degree=degree)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=3e-4, atol=3e-4)


# ---------------------------------------------------------------------------
# Woodbury rank-k update
# ---------------------------------------------------------------------------

def _spd(j, seed, jitter=1.0):
    a = np.random.default_rng(seed).normal(size=(j, j))
    return (a @ a.T / j + jitter * np.eye(j)).astype(np.float32)


@pytest.mark.parametrize("j,h", [(5, 2), (64, 6), (253, 6), (100, 1)])
def test_rank_update_matches_ref(j, h):
    s = _spd(j, 1)
    a = _x(j, h, 2)
    b = _x(h, j, 3)
    got = woodbury.rank_update(s, a, b, bm=32, bn=32)
    want = ref.rank_update(jnp.asarray(s), jnp.asarray(a), jnp.asarray(b))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("j,nc,nr", [(20, 4, 2), (64, 3, 3), (40, 6, 0), (40, 0, 4)])
def test_woodbury_incdec_vs_fresh_inverse(j, nc, nr):
    """The maintained-inverse update must equal inverting the updated S."""
    rng = np.random.default_rng(42)
    s = _spd(j, 5, jitter=float(j))
    s_inv = np.linalg.inv(s.astype(np.float64))
    phi_c = rng.normal(size=(j, nc)) * 0.3
    phi_r = rng.normal(size=(j, nr)) * 0.3
    phi_h = np.concatenate([phi_c, phi_r], axis=1).astype(np.float32)
    signs = np.concatenate([np.ones(nc), -np.ones(nr)]).astype(np.float32)
    if phi_h.shape[1] == 0:
        pytest.skip("empty batch")
    got = woodbury.woodbury_incdec(s_inv.astype(np.float32), phi_h, signs)
    s_new = s.astype(np.float64) + phi_c @ phi_c.T - phi_r @ phi_r.T
    want = np.linalg.inv(s_new)
    np.testing.assert_allclose(np.asarray(got), want, rtol=5e-3, atol=5e-3)


def test_woodbury_zero_columns_are_noop():
    """Zero-padding columns must not change the result (artifact padding)."""
    j = 30
    s_inv = np.linalg.inv(_spd(j, 9, jitter=5.0).astype(np.float64)).astype(np.float32)
    phi = np.random.default_rng(3).normal(size=(j, 2)).astype(np.float32) * 0.2
    signs2 = np.array([1.0, -1.0], np.float32)
    padded = np.concatenate([phi, np.zeros((j, 4), np.float32)], axis=1)
    signs6 = np.concatenate([signs2, np.ones(4, np.float32)])
    got2 = np.asarray(woodbury.woodbury_incdec(s_inv, phi, signs2))
    got6 = np.asarray(woodbury.woodbury_incdec(s_inv, padded, signs6))
    np.testing.assert_allclose(got2, got6, rtol=1e-5, atol=1e-6)


@settings(max_examples=20, deadline=None)
@given(
    j=st.integers(2, 48),
    nc=st.integers(0, 6),
    nr=st.integers(0, 4),
    seed=st.integers(0, 2**31 - 1),
)
def test_woodbury_hypothesis(j, nc, nr, seed):
    if nc + nr == 0:
        return
    rng = np.random.default_rng(seed)
    s = _spd(j, seed, jitter=float(j))
    s_inv = np.linalg.inv(s.astype(np.float64))
    phi_h = (rng.normal(size=(j, nc + nr)) * 0.2).astype(np.float32)
    signs = np.concatenate([np.ones(nc), -np.ones(nr)]).astype(np.float32)
    got = woodbury.woodbury_incdec(s_inv.astype(np.float32), phi_h, signs)
    ph64 = phi_h.astype(np.float64)
    s_new = s.astype(np.float64) + (ph64 * signs) @ ph64.T
    want = np.linalg.inv(s_new)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-2, atol=1e-2)


# ---------------------------------------------------------------------------
# Pure-jnp Gauss-Jordan solver (the no-custom-call replacement for
# jnp.linalg.solve in the AOT path)
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(n=st.integers(1, 8), m=st.integers(1, 6), seed=st.integers(0, 2**31 - 1))
def test_solve_gj_matches_linalg(n, m, seed):
    rng = np.random.default_rng(seed)
    a = rng.normal(size=(n, n)) + n * np.eye(n)
    b = rng.normal(size=(n, m))
    got = woodbury.solve_gj(jnp.asarray(a), jnp.asarray(b))
    want = np.linalg.solve(a, b)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-6, atol=1e-8)


def test_solve_gj_needs_pivoting():
    # zero leading pivot forces a row swap
    a = np.array([[0.0, 1.0], [1.0, 0.0]])
    b = np.array([[2.0], [3.0]])
    got = np.asarray(woodbury.solve_gj(jnp.asarray(a), jnp.asarray(b)))
    np.testing.assert_allclose(got, [[3.0], [2.0]], atol=1e-7)
