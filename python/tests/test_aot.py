"""AOT lowering sanity: every entry lowers to parseable HLO text and the
manifest describes it accurately."""

import os
import tempfile

import jax
import numpy as np
import pytest

from compile import aot, model


def test_all_entries_lower(tmp_path):
    written = aot.lower_all(str(tmp_path))
    names = {os.path.basename(p) for p in written}
    for entry in model.ENTRIES:
        assert f"{entry}.hlo.txt" in names
    assert "manifest.txt" in names
    # Each HLO text must contain an ENTRY computation and typed params.
    for entry in model.ENTRIES:
        text = (tmp_path / f"{entry}.hlo.txt").read_text()
        assert "ENTRY" in text
        assert "parameter(0)" in text


def test_manifest_format(tmp_path):
    aot.lower_all(str(tmp_path), only=["woodbury_incdec"])
    lines = [
        l for l in (tmp_path / "manifest.txt").read_text().splitlines()
        if l and not l.startswith("#")
    ]
    assert lines == [
        "artifact woodbury_incdec "
        "inputs=f32[253,253];f32[253,6];f32[6] outputs=f32[253,253]"
    ]


def test_entry_woodbury_numeric():
    """Executing the jitted entry == oracle, at artifact shapes."""
    from compile.kernels import ref
    rng = np.random.default_rng(5)
    j, h = model.J_POLY2, model.H_MAX
    a = rng.normal(size=(j, j))
    s = a @ a.T / j + 50.0 * np.eye(j)
    s_inv = np.linalg.inv(s).astype(np.float32)
    phi_h = (rng.normal(size=(j, h)) * 0.1).astype(np.float32)
    signs = np.array([1, 1, 1, 1, -1, -1], np.float32)
    (got,) = jax.jit(model.entry_woodbury_incdec)(s_inv, phi_h, signs)
    want = ref.woodbury_incdec(
        s_inv.astype(np.float64), phi_h.astype(np.float64), signs.astype(np.float64)
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=5e-3, atol=5e-4)


def test_entry_predict_batch_numeric():
    rng = np.random.default_rng(6)
    u = rng.normal(size=model.J_POLY2).astype(np.float32)
    b = np.float32(0.7)
    phi_star = rng.normal(size=(model.PRED_BLOCK, model.J_POLY2)).astype(np.float32)
    (got,) = jax.jit(model.entry_predict_batch)(u, b, phi_star)
    np.testing.assert_allclose(
        np.asarray(got), phi_star @ u + 0.7, rtol=1e-4, atol=1e-4
    )
