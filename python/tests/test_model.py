"""L2 correctness: the model-level state transitions vs direct solves.

These tests establish the paper's central claim at the jnp level before the
Rust side reimplements it in f64: incremental/decremental updates produce
exactly the same estimator as retraining from scratch.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref

RHO = 0.5


def _data(n, m, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, m)).astype(np.float32) * 0.5
    w = rng.normal(size=m)
    y = (x @ w + 0.1 * rng.normal(size=n)).astype(np.float32)
    return x, y


def _intrinsic_state(x, y, degree):
    """Build maintained state (s_inv, psum, py, sy, n) directly in f64."""
    phi = np.asarray(ref.phi_poly(jnp.asarray(x), degree=degree), np.float64).T  # (J, N)
    j = phi.shape[0]
    s = phi @ phi.T + RHO * np.eye(j)
    return (
        np.linalg.inv(s),
        phi.sum(axis=1),
        phi @ y.astype(np.float64),
        float(y.sum()),
        float(len(y)),
    )


def test_krr_refresh_matches_direct_solve():
    x, y = _data(60, 5, 1)
    s_inv, psum, py, sy, n = _intrinsic_state(x, y, 2)
    u, b = model.krr_refresh(
        jnp.asarray(s_inv), jnp.asarray(psum), jnp.asarray(py),
        jnp.asarray(sy), jnp.asarray(n),
    )
    phi = ref.phi_poly(jnp.asarray(x), degree=2).T
    u_ref, b_ref = ref.krr_intrinsic_solve(
        jnp.asarray(phi, jnp.float64), jnp.asarray(y, jnp.float64), RHO
    )
    np.testing.assert_allclose(np.asarray(u), np.asarray(u_ref), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(float(b), float(b_ref), rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("degree", [2])
def test_incdec_round_equals_retrain(degree):
    """One +4/-2 round == retrain on the edited dataset (paper's core claim)."""
    x, y = _data(50, 6, 3)
    xc, yc = _data(4, 6, 4)
    r_idx = [7, 23]

    s_inv, psum, py, sy, n = _intrinsic_state(x, y, degree)
    phi_all = np.asarray(ref.phi_poly(jnp.asarray(x), degree=degree), np.float64)
    phi_r = phi_all[r_idx]
    y_r = y[r_idx].astype(np.float64)

    out = model.krr_incdec_round(
        jnp.asarray(s_inv), jnp.asarray(psum), jnp.asarray(py),
        jnp.asarray(sy), jnp.asarray(n),
        jnp.asarray(xc), jnp.asarray(yc, jnp.float32),
        jnp.asarray(phi_r, jnp.float32), jnp.asarray(y_r, jnp.float32),
        degree=degree,
    )
    u_new, b_new = out[5], out[6]

    keep = [i for i in range(len(y)) if i not in r_idx]
    x2 = np.concatenate([x[keep], xc])
    y2 = np.concatenate([y[keep], yc])
    phi2 = ref.phi_poly(jnp.asarray(x2, jnp.float64), degree=degree).T
    u_ref, b_ref = ref.krr_intrinsic_solve(phi2, jnp.asarray(y2, jnp.float64), RHO)
    np.testing.assert_allclose(np.asarray(u_new), np.asarray(u_ref), rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(float(b_new), float(b_ref), rtol=2e-3, atol=2e-3)


def test_empirical_solve_predicts_like_intrinsic():
    """Poly-kernel KRR: empirical and intrinsic solutions give one function."""
    x, y = _data(40, 4, 7)
    xt, _ = _data(10, 4, 8)
    x64, y64 = jnp.asarray(x, jnp.float64), jnp.asarray(y, jnp.float64)
    phi = ref.phi_poly(x64, degree=2).T
    u, b_i = ref.krr_intrinsic_solve(phi, y64, RHO)
    pred_i = ref.predict_intrinsic(u, b_i, ref.phi_poly(jnp.asarray(xt, jnp.float64), degree=2))

    k = ref.gram_poly(x64, x64, degree=2)
    a, b_e = ref.krr_empirical_solve(k, y64, RHO)
    kt = ref.gram_poly(jnp.asarray(xt, jnp.float64), x64, degree=2)
    pred_e = ref.predict_empirical(a, b_e, kt)
    np.testing.assert_allclose(np.asarray(pred_i), np.asarray(pred_e), rtol=1e-6, atol=1e-7)


def test_kbr_update_equals_batch_posterior():
    """k batched KBR updates == batch posterior on the union (eq. 43-44)."""
    sigma_u2, sigma_b2 = 0.01, 0.01
    x, y = _data(30, 4, 9)
    xc, yc = _data(4, 4, 10)
    x64 = jnp.asarray(x, jnp.float64)
    phi = ref.phi_poly(x64, degree=2).T  # (J, N)
    j = phi.shape[0]

    cov0, mean0 = ref.kbr_posterior(phi, jnp.asarray(y, jnp.float64), sigma_u2, sigma_b2)

    phi_c = ref.phi_poly(jnp.asarray(xc, jnp.float64), degree=2).T
    signs = jnp.ones((4,), jnp.float64)
    phi_y = phi @ jnp.asarray(y, jnp.float64) + phi_c @ jnp.asarray(yc, jnp.float64)
    cov1, mean1 = ref.kbr_update(cov0, mean0, phi_c, signs, phi_y, sigma_b2)

    phi_all = jnp.concatenate([phi, phi_c], axis=1)
    y_all = jnp.concatenate([jnp.asarray(y, jnp.float64), jnp.asarray(yc, jnp.float64)])
    cov_ref, mean_ref = ref.kbr_posterior(phi_all, y_all, sigma_u2, sigma_b2)
    np.testing.assert_allclose(np.asarray(cov1), np.asarray(cov_ref), rtol=1e-6, atol=1e-8)
    np.testing.assert_allclose(np.asarray(mean1), np.asarray(mean_ref), rtol=1e-6, atol=1e-8)


def test_kbr_predictive_variance_shrinks_with_data():
    """More data => posterior predictive variance must not grow (sanity)."""
    sigma_u2, sigma_b2 = 0.01, 0.01
    x, y = _data(20, 3, 11)
    xt, _ = _data(5, 3, 12)
    x64 = jnp.asarray(x, jnp.float64)
    pt = ref.phi_poly(jnp.asarray(xt, jnp.float64), degree=2)

    phi_small = ref.phi_poly(x64[:5], degree=2).T
    cov_s, mean_s = ref.kbr_posterior(phi_small, jnp.asarray(y[:5], jnp.float64), sigma_u2, sigma_b2)
    _, psi_small = ref.kbr_predict(cov_s, mean_s, pt, sigma_b2)

    phi_big = ref.phi_poly(x64, degree=2).T
    cov_b, mean_b = ref.kbr_posterior(phi_big, jnp.asarray(y, jnp.float64), sigma_u2, sigma_b2)
    _, psi_big = ref.kbr_predict(cov_b, mean_b, pt, sigma_b2)

    assert np.all(np.asarray(psi_big) <= np.asarray(psi_small) + 1e-9)
    assert np.all(np.asarray(psi_big) >= sigma_b2 - 1e-12)


def test_model_kbr_update_matches_ref():
    """L2 kbr_update (Pallas-cored, f32) vs ref (jnp, f64)."""
    sigma_b2 = model.SIGMA_B2
    rng = np.random.default_rng(13)
    j = 40
    a = rng.normal(size=(j, j))
    cov = np.linalg.inv(a @ a.T / j + 10.0 * np.eye(j))
    phi_h = rng.normal(size=(j, 6)) * 0.05
    signs = np.concatenate([np.ones(4), -np.ones(2)])
    phi_y = rng.normal(size=j)
    got_cov, got_mean = model.kbr_update(
        jnp.asarray(cov, jnp.float32), jnp.asarray(phi_h, jnp.float32),
        jnp.asarray(signs, jnp.float32), jnp.asarray(phi_y, jnp.float32),
        sigma_b2=sigma_b2,
    )
    want_cov, want_mean = ref.kbr_update(
        jnp.asarray(cov), jnp.asarray(mean_zero := np.zeros(j)), jnp.asarray(phi_h),
        jnp.asarray(signs), jnp.asarray(phi_y), sigma_b2,
    )
    np.testing.assert_allclose(np.asarray(got_cov), np.asarray(want_cov), rtol=5e-3, atol=5e-4)
    np.testing.assert_allclose(np.asarray(got_mean), np.asarray(want_mean), rtol=5e-3, atol=5e-3)
