"""L2 JAX model: the paper's update equations as jittable compute graphs.

These functions compose the L1 Pallas kernels (:mod:`compile.kernels`) into
the exact state transitions the Rust coordinator drives at runtime.  Every
public ``*_entry`` function here is an AOT lowering target for
:mod:`compile.aot`; its shapes are fixed by the artifact manifest and the
Rust `runtime::HybridExec` falls back to native linalg when live shapes
do not match.

State carried by the coordinator (intrinsic space, paper Section II):
  s_inv : (J, J)  maintained (Phi Phi^T + rho I)^-1
  psum  : (J,)    Phi e^T    (feature-map row sums)
  py    : (J,)    Phi y^T
  sy    : ()      e y^T
  n     : ()      sample count
The (u, b) head is recovered from that state via the bordered system of
eq. (5) using the Schur complement (eq. 6-7) — O(J^2), no fresh inverse.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernels import feature_map, gram, woodbury


# ---------------------------------------------------------------------------
# Feature maps and Gram blocks (thin wrappers over L1)
# ---------------------------------------------------------------------------

def phi_poly2(x):
    """Intrinsic map, degree 2: (B, M) -> (B, J)."""
    return feature_map.phi_poly(x, degree=2)


def phi_poly3(x):
    """Intrinsic map, degree 3: (B, M) -> (B, J)."""
    return feature_map.phi_poly(x, degree=3)


def gram_poly2(x, y):
    return gram.gram_poly(x, y, degree=2)


def gram_poly3(x, y):
    return gram.gram_poly(x, y, degree=3)


def gram_rbf(x, y, *, gamma: float = 1.0 / (2.0 * 50.0 ** 2)):
    """Paper setting: RBF radius 50 -> gamma = 1/(2 * 50^2)."""
    return gram.gram_rbf(x, y, gamma=gamma)


# ---------------------------------------------------------------------------
# Intrinsic-space incremental state transitions
# ---------------------------------------------------------------------------

def woodbury_incdec(s_inv, phi_h, signs):
    """Batched up/down-date of S^-1 (paper eq. 15), Pallas-cored."""
    return woodbury.woodbury_incdec(s_inv, phi_h, signs)


def krr_refresh(s_inv, psum, py, sy, n):
    """Recover (u, b) from maintained state via the eq. (5) bordered system.

    Solves  [[S, p], [p^T, n]] [u; b] = [py; sy]  with S^-1 available:
      b = (sy - p^T S^-1 py) / (n - p^T S^-1 p)
      u = S^-1 (py - p b)
    """
    sp = s_inv @ psum
    denom = n - psum @ sp
    b = (sy - sp @ py) / denom
    u = s_inv @ py - sp * b
    return u, b


def krr_incdec_round(s_inv, psum, py, sy, n, x_c, y_c, phi_r, y_r, *, degree):
    """One full +|C|/−|R| round in intrinsic space, fused end to end.

    New samples arrive as raw features ``x_c`` (|C|, M) and are mapped by the
    Pallas feature kernel; removed samples arrive as already-mapped rows
    ``phi_r`` (|R|, J) (the coordinator keeps the stored Phi).  Returns the
    complete next state plus the refreshed head.
    """
    phi_c = feature_map.phi_poly(x_c, degree=degree)           # (|C|, J)
    phi_h = jnp.concatenate([phi_c, phi_r], axis=0).T          # (J, H)
    signs = jnp.concatenate([
        jnp.ones((phi_c.shape[0],), jnp.float32),
        -jnp.ones((phi_r.shape[0],), jnp.float32),
    ])
    s_inv_new = woodbury.woodbury_incdec(s_inv, phi_h, signs)
    psum_new = psum + jnp.sum(phi_c, axis=0) - jnp.sum(phi_r, axis=0)
    py_new = py + phi_c.T @ y_c - phi_r.T @ y_r
    sy_new = sy + jnp.sum(y_c) - jnp.sum(y_r)
    n_new = n + jnp.float32(y_c.shape[0]) - jnp.float32(y_r.shape[0])
    u, b = krr_refresh(s_inv_new, psum_new, py_new, sy_new, n_new)
    return s_inv_new, psum_new, py_new, sy_new, n_new, u, b


def predict_batch(u, b, phi_star):
    """y* = Phi* u + b for a (B, J) block of mapped test points."""
    return phi_star @ u + b


# ---------------------------------------------------------------------------
# Kernelized Bayesian Regression (paper Section IV)
# ---------------------------------------------------------------------------

def kbr_update(cov, phi_h, signs, phi_y, *, sigma_b2: float):
    """Batched posterior update (eq. 43-44): returns (cov', mean')."""
    scaled = phi_h / jnp.sqrt(jnp.float32(sigma_b2))
    cov_new = woodbury.woodbury_incdec(cov, scaled, signs)
    mean_new = cov_new @ phi_y / sigma_b2
    return cov_new, mean_new


def kbr_predict(cov, mean, phi_star, *, sigma_b2: float):
    """Predictive head (eq. 49-50): (mu*, psi*) per test row."""
    mu = phi_star @ mean
    psi = sigma_b2 + jnp.sum((phi_star @ cov) * phi_star, axis=1)
    return mu, psi


# ---------------------------------------------------------------------------
# AOT entry points (fixed canonical shapes; see DESIGN.md §6)
# ---------------------------------------------------------------------------
# Canonical config: ECG-like M=21, poly2 -> J=253, |C|=4, |R|=2, H=6.

M_ECG = 21
J_POLY2 = 253
H_MAX = 6
PRED_BLOCK = 64
GRAM_BLOCK = 128
SIGMA_B2 = 0.01


def entry_phi_poly2(x):
    """(H_MAX, M) -> (H_MAX, J)."""
    return (phi_poly2(x),)


def entry_woodbury_incdec(s_inv, phi_h, signs):
    """eq. 15 at canonical shapes."""
    return (woodbury_incdec(s_inv, phi_h, signs),)


def entry_krr_refresh(s_inv, psum, py, sy, n):
    u, b = krr_refresh(s_inv, psum, py, sy, n)
    return (u, b)


def entry_gram_poly2(x, y):
    return (gram_poly2(x, y),)


def entry_gram_rbf(x, y):
    return (gram_rbf(x, y),)


def entry_kbr_update(cov, phi_h, signs, phi_y):
    cov_new, mean_new = kbr_update(cov, phi_h, signs, phi_y, sigma_b2=SIGMA_B2)
    return (cov_new, mean_new)


def entry_predict_batch(u, b, phi_star):
    return (predict_batch(u, b, phi_star),)


def entry_kbr_predict(cov, mean, phi_star):
    mu, psi = kbr_predict(cov, mean, phi_star, sigma_b2=SIGMA_B2)
    return (mu, psi)


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


#: artifact name -> (entry fn, example args).  The AOT driver lowers each
#: with return_tuple=True; the manifest records shapes for the Rust loader.
ENTRIES = {
    "phi_poly2": (entry_phi_poly2, (_spec((H_MAX, M_ECG)),)),
    "woodbury_incdec": (
        entry_woodbury_incdec,
        (_spec((J_POLY2, J_POLY2)), _spec((J_POLY2, H_MAX)), _spec((H_MAX,))),
    ),
    "krr_refresh": (
        entry_krr_refresh,
        (
            _spec((J_POLY2, J_POLY2)),
            _spec((J_POLY2,)),
            _spec((J_POLY2,)),
            _spec(()),
            _spec(()),
        ),
    ),
    "gram_poly2": (
        entry_gram_poly2,
        (_spec((GRAM_BLOCK, M_ECG)), _spec((GRAM_BLOCK, M_ECG))),
    ),
    "gram_rbf": (
        entry_gram_rbf,
        (_spec((GRAM_BLOCK, M_ECG)), _spec((GRAM_BLOCK, M_ECG))),
    ),
    "kbr_update": (
        entry_kbr_update,
        (
            _spec((J_POLY2, J_POLY2)),
            _spec((J_POLY2, H_MAX)),
            _spec((H_MAX,)),
            _spec((J_POLY2,)),
        ),
    ),
    "predict_batch": (
        entry_predict_batch,
        (_spec((J_POLY2,)), _spec(()), _spec((PRED_BLOCK, J_POLY2))),
    ),
    "kbr_predict": (
        entry_kbr_predict,
        (
            _spec((J_POLY2, J_POLY2)),
            _spec((J_POLY2,)),
            _spec((PRED_BLOCK, J_POLY2)),
        ),
    ),
}
