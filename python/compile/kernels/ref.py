"""Pure-jnp reference oracles for the Pallas kernels (L1 correctness).

Every Pallas kernel in this package has an exact counterpart here, written
with plain ``jax.numpy`` so the semantics are unambiguous.  ``pytest`` (and
hypothesis sweeps) assert the Pallas implementations match these oracles to
float tolerance across shapes, dtypes and seeds.

Math references are to the paper:
  B.-W. Chen, N. N. B. Abdullah, S. Park, "Efficient Multiple Incremental
  Computation for Kernel Ridge Regression with Bayesian Uncertainty
  Modeling" (FGCS 2017).
"""

from __future__ import annotations

import itertools
import math

import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# Gram matrices
# ---------------------------------------------------------------------------

def gram_poly(x, y, *, degree: int, coef0: float = 1.0):
    """Polynomial-kernel Gram block: K[i,j] = (x_i . y_j + coef0)^degree."""
    return (x @ y.T + coef0) ** degree


def gram_rbf(x, y, *, gamma: float):
    """RBF Gram block: K[i,j] = exp(-gamma * ||x_i - y_j||^2).

    The paper's "radius r = 50" convention maps to gamma = 1 / (2 r^2).
    """
    x2 = jnp.sum(x * x, axis=1, keepdims=True)
    y2 = jnp.sum(y * y, axis=1, keepdims=True)
    d2 = x2 + y2.T - 2.0 * (x @ y.T)
    d2 = jnp.maximum(d2, 0.0)
    return jnp.exp(-gamma * d2)


def gram_linear(x, y):
    """Linear-kernel Gram block: K[i,j] = x_i . y_j."""
    return x @ y.T


# ---------------------------------------------------------------------------
# Intrinsic feature maps (poly kernels have finite intrinsic dimension
# J = C(M + d, d); RBF has J = inf, hence "inapplicable to intrinsic space")
# ---------------------------------------------------------------------------

def poly_monomials(m: int, degree: int):
    """Enumerate monomials of total degree <= ``degree`` over m variables.

    Each monomial is a tuple of chosen variable indices (with repetition,
    non-decreasing).  The paired coefficient from :func:`poly_coefficients`
    makes  phi(x) . phi(y) == (x . y + coef0)^degree  exactly.
    """
    monos = []
    for k in range(degree + 1):
        monos.extend(itertools.combinations_with_replacement(range(m), k))
    return monos


def poly_coefficients(m: int, degree: int, coef0: float = 1.0):
    """sqrt coefficients aligned with :func:`poly_monomials`."""
    coefs = []
    for mono in poly_monomials(m, degree):
        k = len(mono)
        # multinomial: degree! / (prod alpha_i! * (degree-k)!), where alpha
        # counts repetitions of each variable in the monomial.
        counts: dict[int, int] = {}
        for v in mono:
            counts[v] = counts.get(v, 0) + 1
        denom = math.factorial(degree - k)
        for c in counts.values():
            denom *= math.factorial(c)
        multinom = math.factorial(degree) / denom
        coefs.append(math.sqrt(multinom * (coef0 ** (degree - k))))
    return np.asarray(coefs, dtype=np.float64)


def phi_poly(x, *, degree: int, coef0: float = 1.0):
    """Explicit intrinsic-space map for the poly kernel (oracle, O(B*J)).

    x: (B, M) -> (B, J) with J = C(M + degree, degree).
    """
    x = jnp.asarray(x)
    m = x.shape[1]
    monos = poly_monomials(m, degree)
    coefs = poly_coefficients(m, degree, coef0)
    cols = []
    for mono, c in zip(monos, coefs):
        col = jnp.full((x.shape[0],), float(c), dtype=x.dtype)
        for v in mono:
            col = col * x[:, v]
        cols.append(col)
    return jnp.stack(cols, axis=1)


def intrinsic_dim(m: int, degree: int) -> int:
    """J = C(M + d, d)."""
    return math.comb(m + degree, degree)


# ---------------------------------------------------------------------------
# Woodbury batched incremental/decremental update (paper eq. 15)
# ---------------------------------------------------------------------------

def woodbury_incdec(s_inv, phi_h, signs):
    """One-shot batched up/down-date of a maintained inverse.

    S[l+1]^-1 = (S + sum_c phi_c phi_c^T - sum_r phi_r phi_r^T)^-1
              = S^-1 - S^-1 Phi_H (I + Phi_H' S^-1 Phi_H)^-1 Phi_H' S^-1
    with Phi_H = [Phi_C | Phi_R]  (J, H)  and  Phi_H' = [Phi_C | -Phi_R]^T.

    ``signs`` is the (H,) vector of +1 (incremental) / -1 (decremental).
    A zero column in phi_h with any sign is a no-op (used for padding).
    """
    t = s_inv @ phi_h                                  # (J, H)
    core = jnp.eye(phi_h.shape[1], dtype=s_inv.dtype) + (signs[:, None] * phi_h.T) @ t
    w = jnp.linalg.solve(core, signs[:, None] * t.T)   # (H, J)
    return s_inv - t @ w


def rank_update(s, a, b):
    """S - A @ B  (the O(J^2 H) correction GEMM the Pallas kernel computes)."""
    return s - a @ b


# ---------------------------------------------------------------------------
# KRR heads
# ---------------------------------------------------------------------------

def krr_intrinsic_solve(phi, y, rho: float):
    """Direct intrinsic-space KRR (paper eq. 5), returns (u, b).

    phi: (J, N), y: (N,).  Solves the bordered system of eq. (5) exactly.
    """
    j, n = phi.shape
    s = phi @ phi.T + rho * jnp.eye(j, dtype=phi.dtype)
    pe = jnp.sum(phi, axis=1)                     # Phi e^T
    top = jnp.concatenate([s, pe[:, None]], axis=1)
    bot = jnp.concatenate(
        [pe[None, :], jnp.array([[float(n)]], dtype=phi.dtype)], axis=1
    )
    aug = jnp.concatenate([top, bot], axis=0)
    rhs = jnp.concatenate([phi @ y, jnp.sum(y)[None]])
    sol = jnp.linalg.solve(aug, rhs)
    return sol[:j], sol[j]


def krr_empirical_solve(k, y, rho: float):
    """Direct empirical-space KRR (paper eq. 18-19), returns (a, b)."""
    n = k.shape[0]
    q_inv = jnp.linalg.inv(k + rho * jnp.eye(n, dtype=k.dtype))
    e = jnp.ones((n,), dtype=k.dtype)
    b = (y @ q_inv @ e) / (e @ q_inv @ e)
    a = q_inv @ (y - b)
    return a, b


def predict_intrinsic(u, b, phi_star):
    """y* = Phi*^T u + b;  phi_star: (B, J)."""
    return phi_star @ u + b


def predict_empirical(a, b, k_star):
    """y* = K(*, train) a + b;  k_star: (B, N)."""
    return k_star @ a + b


# ---------------------------------------------------------------------------
# Kernelized Bayesian Regression (paper eq. 41-50)
# ---------------------------------------------------------------------------

def kbr_posterior(phi, y, sigma_u2: float, sigma_b2: float):
    """Batch posterior (eq. 41-42) with mu_u = 0 prior.

    phi: (J, N).  Returns (Sigma_{u|y,Phi}, mu_{u|y,Phi}).
    """
    j = phi.shape[0]
    prec = jnp.eye(j, dtype=phi.dtype) / sigma_u2 + (phi @ phi.T) / sigma_b2
    cov = jnp.linalg.inv(prec)
    mean = cov @ (phi @ y) / sigma_b2
    return cov, mean


def kbr_update(cov, mean, phi_h, signs, phi_y, sigma_b2: float):
    """Batched incremental/decremental posterior update (eq. 43-44).

    The posterior precision is  Sigma^-1 = Sigma_u^-1 + sigma_b^-2 Phi Phi^T,
    so adding/removing samples adds  sigma_b^-2 Phi_H Phi_H'  to the
    precision; Woodbury turns that into a covariance update.  The mean is
    then  mean' = cov' @ (sigma_b^-2 Phi y^T)  for the mu_u = 0 prior.

    ``phi_y`` is the already-updated  Phi y^T  (J,) running sum.
    """
    scaled = phi_h / math.sqrt(sigma_b2)
    cov_new = woodbury_incdec(cov, scaled, signs)
    mean_new = cov_new @ phi_y / sigma_b2
    return cov_new, mean_new


def kbr_predict(cov, mean, phi_star, sigma_b2: float):
    """Predictive distribution (eq. 47-50): returns (mu*, psi*) per row."""
    mu = phi_star @ mean
    psi = sigma_b2 + jnp.sum((phi_star @ cov) * phi_star, axis=1)
    return mu, psi
