"""L1 Pallas kernel: the rank-k Woodbury correction GEMM (paper eq. 15).

The batched incremental/decremental update

    S' = S^-1 - T W,   T = S^-1 Phi_H (J, H),   W = core^-1 Phi_H' S^-1 (H, J)

spends essentially all of its O(J^2 H) flops in the final `S^-1 - T @ W`
correction (the core solve is only O(H^3), H ~ 6).  This kernel computes
that correction as a tiled fused multiply-subtract so the maintained inverse
is updated in one pass over its (J, J) extent.

TPU mapping: each (BM, BN) output tile does a (BM, H) x (H, BN) matmul on
the MXU and subtracts from the resident S tile — one HBM read of S, one
write of S', with T/W streamed into VMEM once per row/col of the grid.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BM = 128
DEFAULT_BN = 128


def _rank_update_kernel(s_ref, a_ref, b_ref, o_ref):
    """One output tile of  S - A @ B."""
    a = a_ref[...]
    b = b_ref[...]
    prod = jax.lax.dot_general(
        a, b, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    o_ref[...] = s_ref[...] - prod


def _pad_axis(a, axis, multiple):
    rem = (-a.shape[axis]) % multiple
    if rem == 0:
        return a
    pad = [(0, 0)] * a.ndim
    pad[axis] = (0, rem)
    return jnp.pad(a, pad)


def rank_update(s, a, b, *, bm: int = DEFAULT_BM, bn: int = DEFAULT_BN):
    """Tiled  S - A @ B  with S: (J, J'), A: (J, H), B: (H, J')."""
    s = jnp.asarray(s, jnp.float32)
    a = jnp.asarray(a, jnp.float32)
    b = jnp.asarray(b, jnp.float32)
    j0, j1 = s.shape
    h = a.shape[1]
    sp = _pad_axis(_pad_axis(s, 0, bm), 1, bn)
    ap = _pad_axis(a, 0, bm)
    bp = _pad_axis(b, 1, bn)
    grid = (sp.shape[0] // bm, sp.shape[1] // bn)
    out = pl.pallas_call(
        _rank_update_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm, h), lambda i, j: (i, 0)),
            pl.BlockSpec((h, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct(sp.shape, jnp.float32),
        interpret=True,
    )(sp, ap, bp)
    return out[:j0, :j1]


def solve_gj(a, b):
    """Solve ``a x = b`` (small fixed n) by Gauss-Jordan with partial
    pivoting, written in pure jnp ops.

    ``jnp.linalg.solve`` lowers to a LAPACK typed-FFI custom-call on CPU,
    which xla_extension 0.5.1 (the Rust runtime's XLA) cannot compile —
    this keeps the AOT artifacts plain-HLO.  n is the Woodbury core size
    (H ~ 6), so the unrolled python loop is tiny.
    """
    a = jnp.asarray(a)
    b = jnp.asarray(b)
    n = a.shape[0]
    aug = jnp.concatenate([a, b], axis=1)
    rows = jnp.arange(n)
    for col in range(n):
        colvals = jnp.abs(aug[:, col])
        piv = jnp.argmax(jnp.where(rows >= col, colvals, -1.0))
        row_col = aug[col]
        row_piv = aug[piv]
        aug = aug.at[col].set(row_piv).at[piv].set(row_col)
        aug = aug.at[col].set(aug[col] / aug[col, col])
        factors = aug[:, col].at[col].set(0.0)
        aug = aug - factors[:, None] * aug[col][None, :]
    return aug[:, n:]


def woodbury_incdec(s_inv, phi_h, signs):
    """Full batched up/down-date (eq. 15) with the Pallas correction GEMM.

    s_inv: (J, J) maintained inverse; phi_h: (J, H) batch columns;
    signs: (H,) +1 for incremental columns, -1 for decremental ones.
    Zero columns are exact no-ops, which the AOT artifact exploits to pad
    variable |H| < H_max batches.
    """
    s_inv = jnp.asarray(s_inv, jnp.float32)
    phi_h = jnp.asarray(phi_h, jnp.float32)
    signs = jnp.asarray(signs, jnp.float32)
    t = s_inv @ phi_h                                   # (J, H)
    core = jnp.eye(phi_h.shape[1], dtype=jnp.float32) + (signs[:, None] * phi_h.T) @ t
    w = solve_gj(core, signs[:, None] * t.T)            # (H, J)
    return rank_update(s_inv, t, w)
