"""L1 Pallas kernel: explicit intrinsic-space feature map for poly kernels.

Intrinsic-space KRR (paper Section II) operates on phi(x) in R^J with
J = C(M + d, d).  Each component of phi is a scaled monomial

    phi_j(x) = coef_j * prod_t x[idx(t, j)]

where the monomial table (idx, coef) is precomputed host-side from the
kernel degree (see :func:`compile.kernels.ref.poly_monomials`).  Padding
monomials shorter than d with a synthetic "ones" feature (index M) turns
the map into a uniform d-way gather-product, which vectorizes cleanly: the
kernel tiles the batch dimension and keeps the whole (d, J) index table and
(J,) coefficient row resident (J <= 2024 for the paper's configs, i.e.
<= 2024*4B coefficients + d*2024*4B indices — trivially VMEM-resident).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from . import ref

DEFAULT_BM = 128


@functools.lru_cache(maxsize=32)
def monomial_table(m: int, degree: int, coef0: float = 1.0):
    """(idx, coef): idx is (degree, J) int32 into the M+1-wide augmented x
    (index M selects the constant-1 column); coef is (J,) float32."""
    monos = ref.poly_monomials(m, degree)
    coefs = ref.poly_coefficients(m, degree, coef0)
    j = len(monos)
    idx = np.full((degree, j), m, dtype=np.int32)  # pad with the ones column
    for col, mono in enumerate(monos):
        for t, v in enumerate(mono):
            idx[t, col] = v
    return idx, coefs.astype(np.float32)


def _phi_kernel(xa_ref, idx_ref, coef_ref, o_ref, *, degree):
    """One batch tile of the gather-product feature map."""
    xa = xa_ref[...]            # (bm, M+1)
    idx = idx_ref[...]          # (degree, J)
    coef = coef_ref[...]        # (1, J)
    acc = jnp.broadcast_to(coef, (xa.shape[0], coef.shape[1]))
    for t in range(degree):
        acc = acc * jnp.take(xa, idx[t], axis=1)
    o_ref[...] = acc


def phi_poly(x, *, degree: int, coef0: float = 1.0, bm: int = DEFAULT_BM):
    """phi(x) for the poly kernel: (B, M) -> (B, J), f32, Pallas-tiled."""
    x = jnp.asarray(x, jnp.float32)
    b, m = x.shape
    idx_np, coef_np = monomial_table(m, degree, coef0)
    j = coef_np.shape[0]
    xa = jnp.concatenate([x, jnp.ones((b, 1), jnp.float32)], axis=1)
    rem = (-b) % bm
    if rem:
        xa = jnp.pad(xa, ((0, rem), (0, 0)))
    grid = (xa.shape[0] // bm,)
    out = pl.pallas_call(
        functools.partial(_phi_kernel, degree=degree),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, m + 1), lambda i: (i, 0)),
            pl.BlockSpec((degree, j), lambda i: (0, 0)),
            pl.BlockSpec((1, j), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, j), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((xa.shape[0], j), jnp.float32),
        interpret=True,
    )(xa, jnp.asarray(idx_np), jnp.asarray(coef_np)[None, :])
    return out[:b]
