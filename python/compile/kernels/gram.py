"""L1 Pallas kernels: blocked Gram-matrix computation.

The Gram matrix is the paper's first hot-spot: the empirical-space mode
(Section III) maintains Q = K + rho*I over the full training set, and every
incremental batch needs the cross-Gram between the new samples and the
existing set.  The kernels here tile the (N, N') output into (BM, BN) blocks
— the full feature dimension M rides along inside a block because M is small
in the N >> M regime (ECG: M = 21), which is exactly when the Gram path is
used at scale.

TPU mapping (DESIGN.md §Hardware-Adaptation): each (BM, BN) block is one
MXU-friendly matmul of shape (BM, M) x (M, BN); BlockSpec's index_map
expresses the HBM->VMEM schedule.  ``interpret=True`` is mandatory on this
CPU-only image — real TPU lowering emits a Mosaic custom-call the CPU PJRT
plugin cannot execute.

All kernels are verified against :mod:`compile.kernels.ref` by pytest.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes: lane-width friendly (multiples of 8x128 for f32 on
# TPU); on CPU-interpret they just define the blocking structure.
DEFAULT_BM = 128
DEFAULT_BN = 128


def _pad_rows(a, multiple):
    """Zero-pad the leading axis of ``a`` up to a multiple of ``multiple``."""
    n = a.shape[0]
    rem = (-n) % multiple
    if rem == 0:
        return a, n
    pad = [(0, rem)] + [(0, 0)] * (a.ndim - 1)
    return jnp.pad(a, pad), n


def _gram_poly_kernel(x_ref, y_ref, o_ref, *, degree, coef0):
    """One (BM, BN) output block of the poly Gram: (X Y^T + c)^d."""
    x = x_ref[...]
    y = y_ref[...]
    acc = jax.lax.dot_general(
        x, y, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    o_ref[...] = (acc + coef0) ** degree


def _gram_rbf_kernel(x_ref, y_ref, o_ref, *, gamma):
    """One (BM, BN) output block of the RBF Gram: exp(-g ||x-y||^2)."""
    x = x_ref[...]
    y = y_ref[...]
    xy = jax.lax.dot_general(
        x, y, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    x2 = jnp.sum(x * x, axis=1, keepdims=True)
    y2 = jnp.sum(y * y, axis=1, keepdims=True)
    d2 = jnp.maximum(x2 + y2.T - 2.0 * xy, 0.0)
    o_ref[...] = jnp.exp(-gamma * d2)


def _blocked_gram(kernel_fn, x, y, bm, bn):
    """Shared pallas_call driver: pad to tile multiples, run grid, slice."""
    x = jnp.asarray(x, jnp.float32)
    y = jnp.asarray(y, jnp.float32)
    m = x.shape[1]
    xp, n_x = _pad_rows(x, bm)
    yp, n_y = _pad_rows(y, bn)
    grid = (xp.shape[0] // bm, yp.shape[0] // bn)
    out = pl.pallas_call(
        kernel_fn,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, m), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, m), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((xp.shape[0], yp.shape[0]), jnp.float32),
        interpret=True,
    )(xp, yp)
    return out[:n_x, :n_y]


def gram_poly(x, y, *, degree: int, coef0: float = 1.0,
              bm: int = DEFAULT_BM, bn: int = DEFAULT_BN):
    """Blocked polynomial Gram matrix, K[i,j] = (x_i . y_j + coef0)^degree."""
    kern = functools.partial(_gram_poly_kernel, degree=degree, coef0=coef0)
    return _blocked_gram(kern, x, y, bm, bn)


def gram_rbf(x, y, *, gamma: float,
             bm: int = DEFAULT_BM, bn: int = DEFAULT_BN):
    """Blocked RBF Gram matrix, K[i,j] = exp(-gamma ||x_i - y_j||^2)."""
    kern = functools.partial(_gram_rbf_kernel, gamma=gamma)
    return _blocked_gram(kern, x, y, bm, bn)
