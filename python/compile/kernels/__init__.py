"""L1 Pallas kernels for the mikrr compile path.

Modules:
  gram        — blocked Gram-matrix kernels (poly / RBF)
  feature_map — explicit intrinsic-space feature map (gather-product)
  woodbury    — rank-k Woodbury correction GEMM (paper eq. 15 hot-spot)
  ref         — pure-jnp oracles for all of the above
"""

from . import feature_map, gram, ref, woodbury  # noqa: F401
